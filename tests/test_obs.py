"""Observability layer: hierarchical spans, the jit tracer guard,
Chrome-trace export/validation, the metrics registry, and trace-sourced
drift attribution (PR 7)."""

import json

import pytest

import jax
import jax.numpy as jnp

from repro.fleet import ExchangeTelemetry
from repro.measure.decisions import Decision, DecisionCache
from repro.obs import (
    MetricsRegistry,
    Span,
    Tracer,
    aggregate_events,
    aggregate_spans,
    attribute_program_iteration,
    default_metrics,
    load_chrome_trace,
    publish_comm_stats,
    save_chrome_trace,
    summary,
    to_chrome_trace,
    validate,
)


# ===========================================================================
# Tracer: recording, nesting, the jit guard
# ===========================================================================

class TestTracer:
    def test_spans_nest_by_open_context(self):
        tr = Tracer()
        with tr.span("outer") as o:
            with tr.span("inner") as i:
                pass
        assert o.parent_id is None
        assert i.parent_id == o.span_id
        assert o.duration >= i.duration >= 0.0

    def test_disabled_tracer_records_nothing(self):
        tr = Tracer(enabled=False)
        with tr.span("x") as sp:
            assert sp is None
        assert tr.add_manual("y", 0.0, 1.0) is None
        assert len(tr) == 0

    def test_span_cap_drops_and_counts(self):
        tr = Tracer(max_spans=2)
        for _ in range(5):
            with tr.span("s"):
                pass
        assert len(tr) == 2
        assert tr.dropped == 3
        tr.clear()
        assert len(tr) == 0 and tr.dropped == 0

    def test_attrs_mutable_until_exit(self):
        tr = Tracer()
        with tr.span("exchange") as sp:
            sp.attrs.update(fingerprint="fp", strategy="wire/uniform")
        assert tr.spans[0].attrs["fingerprint"] == "fp"

    def test_no_spans_inside_jit(self):
        # the tracer guard: a perf_counter pair inside a jax trace
        # measures tracing, not transfer — span() must record nothing
        tr = Tracer()
        seen = []

        @jax.jit
        def f(x):
            with tr.span("should-not-record") as sp:
                seen.append(sp)
            return x + 1

        f(jnp.zeros(4))
        assert seen == [None]
        assert len(tr) == 0
        assert not any(s.name == "should-not-record" for s in tr.spans)

    def test_no_spans_inside_shard_map(self):
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.compat import shard_map

        tr = Tracer()
        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

        def f(x):
            with tr.span("should-not-record") as sp:
                assert sp is None
            return x

        shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(jnp.zeros(4))
        assert len(tr) == 0

    def test_add_manual_nests_under_open_span(self):
        tr = Tracer()
        with tr.span("exchange") as ex:
            tr.add_manual("plan", 0.0, 1e-4, nsegments=3)
        plan = [s for s in tr.spans if s.name == "plan"][0]
        assert plan.parent_id == ex.span_id
        assert plan.attrs["nsegments"] == 3
        # explicit parent wins over the (now empty) stack
        child = tr.add_manual("pack", 0.0, 1e-5, parent=ex)
        assert child.parent_id == ex.span_id
        # no parent, empty stack -> root
        root = tr.add_manual("loose", 0.0, 1e-5)
        assert root.parent_id is None


def test_communicator_sendrecv_records_phase_spans(monkeypatch):
    # eager blocking sendrecv under the tracer: one exchange span
    # carrying the decision signature, with pack/wire/unpack children in
    # execution order.  The wire op is stubbed to a self-send (no eager
    # collective eval on CPU); pack/unpack run for real.
    from repro.comm import api
    from repro.core import BYTE, Vector

    monkeypatch.setattr(api.lax, "ppermute", lambda x, axis, perm: x)
    tr = Tracer()
    comm = api.Communicator(axis_name="x", tracer=tr)
    ct = comm.commit(Vector(4, 8, 16, BYTE))
    buf = jnp.arange(ct.extent, dtype=jnp.uint8)
    comm.sendrecv(buf, jnp.zeros_like(buf), ct, [(0, 0)])

    ex = [s for s in tr.spans if s.name == "exchange"]
    assert len(ex) == 1
    assert ex[0].attrs["fingerprint"] == ct.fingerprint
    assert ex[0].attrs["strategy"]
    assert ex[0].attrs["pred"] > 0.0
    kids = [s for s in tr.spans if s.parent_id == ex[0].span_id]
    assert [s.name for s in kids] == ["pack", "wire", "unpack"]
    assert all(s.attrs["pred"] >= 0.0 for s in kids)
    assert all(not s.attrs.get("attributed") for s in kids)


def test_communicator_sendrecv_under_jit_records_nothing(monkeypatch):
    from repro.comm import api
    from repro.core import BYTE, Vector

    monkeypatch.setattr(api.lax, "ppermute", lambda x, axis, perm: x)
    tr = Tracer()
    comm = api.Communicator(axis_name="x", tracer=tr)
    ct = comm.commit(Vector(4, 8, 16, BYTE))

    @jax.jit
    def step(buf):
        return comm.sendrecv(buf, jnp.zeros_like(buf), ct, [(0, 0)])

    step(jnp.arange(ct.extent, dtype=jnp.uint8))
    assert len(tr) == 0


def test_communicator_neighbor_alltoallv_span_hierarchy(monkeypatch):
    # the fused path: exchange > {plan, pack, wire, unpack}, decision
    # signature (plan fingerprint + schedule) on the exchange span
    from repro.comm import api
    from repro.core import BYTE, Vector

    monkeypatch.setattr(api.lax, "ppermute", lambda x, axis, perm: x)
    tr = Tracer()
    comm = api.Communicator(
        axis_name="x", tracer=tr, decisions=DecisionCache()
    )
    cts = [comm.commit(Vector(4, 8, 16, BYTE)),
           comm.commit(Vector(2, 16, 32, BYTE))]
    buf = jnp.arange(max(ct.extent for ct in cts), dtype=jnp.uint8)
    comm.neighbor_alltoallv(
        buf, cts, cts, [((0, 0),), ((0, 0),)]
    )

    by_name = {}
    for s in tr.spans:
        by_name.setdefault(s.name, []).append(s)
    assert len(by_name["exchange"]) == 1
    ex = by_name["exchange"][0]
    assert ex.attrs["strategy"].startswith("wire/")
    assert ex.attrs["fingerprint"]
    assert ex.attrs["wire_bytes"] > 0
    # plan/pack/wire/unpack all nest (directly) under the exchange
    for name in ("plan", "pack", "wire", "unpack"):
        assert by_name[name][0].parent_id == ex.span_id, name
    # the plan span carries its own prediction for the drift join
    assert by_name["plan"][0].attrs["pred"] > 0.0
    # the decision signature joins the decisions cache by fingerprint
    assert any(
        d.fingerprint == ex.attrs["fingerprint"]
        for d in comm.model.decisions.log
    )


# ===========================================================================
# attributed program iterations
# ===========================================================================

def _program(comm):
    from repro.halo.program import build_halo_program

    return build_halo_program((1, 1, 1), (8, 8, 8), comm, steps=2)


class TestAttributeProgramIteration:
    def test_span_tree_shape_and_scaling(self):
        from repro.comm.api import Communicator
        from repro.fleet import predict_program_phases

        comm = Communicator(axis_name="data", decisions=DecisionCache())
        program = _program(comm)
        phases = predict_program_phases(program, comm.model)
        tr = Tracer()
        it = attribute_program_iteration(
            tr, program, t0=10.0, seconds=2e-3, phases=phases, iteration=7
        )
        assert it.duration == pytest.approx(2e-3)
        assert it.attrs["iteration"] == 7
        assert it.attrs["strategy"] == f"program/s={program.steps}"
        assert it.attrs["attributed"] is True
        ex = [s for s in tr.spans if s.name == "exchange"]
        assert len(ex) == 1 and ex[0].parent_id == it.span_id
        assert ex[0].attrs["fingerprint"] == program.fingerprint
        st = [s for s in tr.spans if s.name == "stencil"]
        assert len(st) == program.applications
        # the children partition the observed iteration exactly
        leaf = [s for s in tr.spans if s.name in
                ("pack", "wire", "unpack", "stencil")]
        assert sum(s.duration for s in leaf) == pytest.approx(2e-3)
        # ...in the model's predicted proportions
        pk = [s for s in tr.spans if s.name == "pack"][0]
        total = sum(phases.values())
        assert pk.duration == pytest.approx(
            2e-3 * phases["pack"] / total
        )

    def test_zero_prediction_records_nothing(self):
        tr = Tracer()
        assert attribute_program_iteration(
            tr, object(), 0.0, 1e-3, {"pack": 0.0}
        ) is None
        assert len(tr) == 0


def test_run_smoother_traced_exchanges_bounded_by_iterations():
    # the launch loop records one attributed iteration tree per compiled
    # iteration: exchanges <= iterations is the communication-avoidance
    # invariant the CI trace check gates on
    from repro.comm.api import Communicator
    from repro.launch.smoother import run_smoother

    tr = Tracer()
    comm = Communicator(
        axis_name="data", decisions=DecisionCache(), tracer=tr
    )
    report = run_smoother(comm, iters=3, interior=(8, 8, 8),
                          cycle="smooth", halo_steps=2)
    iters = [s for s in tr.spans if s.name == "program_iteration"]
    ex = [s for s in tr.spans if s.name == "exchange"]
    assert len(iters) == 3
    assert len(ex) <= len(iters)
    assert all(s.attrs["fingerprint"] == report.program.fingerprint
               for s in ex)
    assert all(s.attrs.get("attributed") for s in iters)


# ===========================================================================
# export: Chrome trace, aggregation, summary, validation
# ===========================================================================

def _sample_tracer() -> Tracer:
    tr = Tracer()
    it = tr.add_manual("program_iteration", 0.0, 1e-3,
                       fingerprint="fp1", strategy="program/s=2", steps=2)
    ex = tr.add_manual("exchange", 0.0, 6e-4, parent=it,
                       fingerprint="fp1", strategy="program/s=2",
                       schedule="uniform", wire_bytes=4096, pred=5e-4)
    tr.add_manual("pack", 0.0, 2e-4, parent=ex, pred=1e-4)
    tr.add_manual("wire", 2e-4, 2e-4, parent=ex, pred=2e-4)
    tr.add_manual("unpack", 4e-4, 2e-4, parent=ex, pred=2e-4)
    tr.add_manual("stencil", 6e-4, 2e-4, parent=it, pred=1e-4)
    tr.add_manual("stencil", 8e-4, 2e-4, parent=it, pred=1e-4)
    return tr


class TestExport:
    def test_chrome_trace_round_trip(self, tmp_path):
        tr = _sample_tracer()
        p = save_chrome_trace(tr, tmp_path / "t.json")
        trace = load_chrome_trace(p)
        assert trace["otherData"]["generator"] == "repro.obs"
        assert len(trace["traceEvents"]) == len(tr.spans)
        ev = trace["traceEvents"][1]
        assert ev["ph"] == "X" and ev["cat"] == "comm"
        assert ev["args"]["fingerprint"] == "fp1"
        assert ev["args"]["parent_id"] == tr.spans[0].span_id
        # aggregates computed from the file match the live tracer's
        # (timestamps round-trip through integer-ish microseconds)
        live = tr.phase_aggregates()
        from_file = aggregate_events(trace)
        assert set(from_file) == set(live)
        for fp, rec in live.items():
            assert set(from_file[fp]) == set(rec)
            for ph, r in rec.items():
                for k, v in r.items():
                    assert from_file[fp][ph][k] == pytest.approx(v), (ph, k)

    def test_numpy_attrs_export_jsonable(self, tmp_path):
        import numpy as np

        tr = Tracer()
        tr.add_manual("exchange", 0.0, 1e-4, fingerprint="f",
                      strategy="s", wire_bytes=np.int64(4096))
        s = json.dumps(to_chrome_trace(tr))
        assert json.loads(s)["traceEvents"][0]["args"]["wire_bytes"] == 4096

    def test_aggregate_credits_nearest_fingerprinted_ancestor(self):
        agg = aggregate_spans(_sample_tracer().spans)
        assert set(agg) == {"fp1"}
        rec = agg["fp1"]
        # pack/wire/unpack credited through the exchange, stencil
        # through the iteration — same decision key
        assert rec["pack"]["count"] == 1
        assert rec["stencil"]["count"] == 2
        assert rec["stencil"]["observed"] == pytest.approx(4e-4)
        assert rec["wire"]["predicted"] == pytest.approx(2e-4)
        # unparented phase spans are not credited anywhere
        lone = Tracer()
        lone.add_manual("pack", 0.0, 1e-4)
        assert aggregate_spans(lone.spans) == {}

    def test_summary_joins_observed_and_predicted(self):
        text = summary(to_chrome_trace(_sample_tracer()))
        assert "program_iteration" in text
        assert "fp1" in text and "program/s=2" in text
        assert "obs/pred" in text
        assert "uniform/4096B" in text
        # observed 2e-4 vs predicted 1e-4 on pack -> ratio 2.000
        assert "2.000" in text

    def test_validate_passes_well_formed(self):
        assert validate(to_chrome_trace(_sample_tracer())) == []

    def test_validate_flags_unsigned_exchange(self):
        tr = Tracer()
        tr.add_manual("exchange", 0.0, 1e-4, strategy="wire/uniform")
        errs = validate(to_chrome_trace(tr))
        assert any("fingerprint missing" in e for e in errs)

    def test_validate_flags_multi_exchange_iteration(self):
        tr = Tracer()
        it = tr.add_manual("program_iteration", 0.0, 1e-3,
                           fingerprint="f", strategy="program/s=2")
        for i in range(2):
            tr.add_manual("exchange", 0.0, 1e-4, parent=it,
                          fingerprint="f", strategy="s")
        errs = validate(to_chrome_trace(tr))
        assert any("2 exchanges in one iteration" in e for e in errs)

    def test_validate_flags_malformed_json(self):
        assert validate({}) == ["traceEvents missing or not a list"]
        errs = validate({"traceEvents": [{"name": "x", "ph": "B"}]})
        assert any("ph" in e for e in errs)

    def test_cli_validate_and_summary(self, tmp_path, capsys):
        from repro.obs.__main__ import main

        p = save_chrome_trace(_sample_tracer(), tmp_path / "t.json")
        assert main(["validate", str(p)]) == 0
        assert "trace OK" in capsys.readouterr().out
        assert main(["summary", str(p)]) == 0
        assert "program_iteration" in capsys.readouterr().out
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"traceEvents": [{"ph": "B"}]}))
        assert main(["validate", str(bad)]) == 1
        assert main(["validate", str(tmp_path / "missing.json")]) == 2


# ===========================================================================
# metrics
# ===========================================================================

class TestMetricsRegistry:
    def test_counters_and_gauges(self):
        m = MetricsRegistry()
        m.inc("a")
        m.inc("a", 2)
        m.set_gauge("g", 0.5)
        assert m.counter("a") == 3.0
        assert m.gauge("g") == 0.5
        assert len(m) == 2
        snap = m.snapshot()
        assert snap == {"counters": {"a": 3.0}, "gauges": {"g": 0.5}}

    def test_save_load_round_trip(self, tmp_path):
        m = MetricsRegistry()
        m.set_counter("comm.exchanges", 7)
        m.set_gauge("occ", 0.25)
        p = m.save(tmp_path / "metrics.json")
        back = MetricsRegistry.load(p)
        assert back.snapshot() == m.snapshot()
        # absent file -> empty registry
        assert len(MetricsRegistry.load(tmp_path / "nope.json")) == 0
        # format mismatch refused
        p.write_text(json.dumps({"format": 99}))
        with pytest.raises(ValueError, match="format"):
            MetricsRegistry.load(p)

    def test_report_renders_both_kinds(self):
        m = MetricsRegistry()
        m.inc("c", 2)
        m.set_gauge("g", 0.125)
        rep = m.report()
        assert "counter" in rep and "gauge" in rep and "0.1250" in rep


def test_publish_comm_stats_maps_counters_and_occupancy():
    tel = ExchangeTelemetry(capacity=4)
    tel.observe("k", 1e-4)
    tel.observe("k", 1e-4)
    m = MetricsRegistry()
    publish_comm_stats(
        {"wire_ops": 5, "wire_payload_bytes": 1024,
         "committed_types": 3, "commit_hits": 1,
         "model_lookups": 10, "model_hits": 4},
        telemetry=tel, registry=m,
    )
    assert m.counter("comm.exchanges") == 5
    assert m.counter("comm.wire_payload_bytes") == 1024
    assert m.counter("decisions.cache_hits") == 4
    assert m.counter("decisions.cache_misses") == 6
    assert m.counter("telemetry.observations") == 2
    assert m.gauge("telemetry.ring_occupancy") == pytest.approx(0.5)


def test_communicator_stats_publishes_to_default_registry():
    from repro.comm.api import Communicator

    comm = Communicator(axis_name="x")
    comm.stats()
    assert default_metrics().counter("comm.exchanges") >= 0
    assert "comm.committed_types" in default_metrics().snapshot()["counters"]


# ===========================================================================
# trace-sourced drift attribution
# ===========================================================================

def _decisions() -> DecisionCache:
    return DecisionCache([
        Decision("prog1", 0, 1, True, "program/s=2", 1e-5, 3e-5, 0.0,
                 "deep halo", 2048),
        Decision("ct1", 1, 1, True, "rows", 2e-6, 1e-5, 3e-6, "vec", 1024),
    ])


def _trace_agg(obs_scale: float, count: int = 4, key: str = "prog1") -> dict:
    # phase aggregates as Tracer.phase_aggregates() shapes them
    return {key: {
        ph: {"count": count, "observed": obs_scale * pred,
             "predicted": pred, "attributed": 0}
        for ph, pred in
        (("pack", 1e-5), ("wire", 2e-5), ("unpack", 1e-5),
         ("stencil", 4e-5))
    }}


class TestTraceDrift:
    def test_trace_gives_direct_term_attribution(self):
        from repro.comm.perfmodel import TPU_V5E
        from repro.fleet import DriftDetector

        trace = {**_trace_agg(10.0), **_trace_agg(10.0, key="ct1")}
        rep = DriftDetector(threshold=3.0, min_samples=4).audit(
            _decisions(), TPU_V5E, trace=trace
        )
        by_fp = {f.fingerprint: f for f in rep.findings}
        prog = by_fp["prog1"]
        assert prog.source == "trace"
        assert prog.drifted
        assert prog.samples == 4
        # program rows price wire + stencil terms; the trace supplies
        # both ratios directly
        assert set(prog.phase_ratios) == {"wire", "stencil"}
        assert prog.term in ("wire", "stencil")
        assert prog.phase_ratios["wire"] == pytest.approx(10.0)
        # a point-to-point row pools pack+unpack into pack_unpack
        ct = by_fp["ct1"]
        assert ct.source == "trace" and ct.drifted
        assert set(ct.phase_ratios) == {"wire", "pack_unpack"}
        assert ct.phase_ratios["pack_unpack"] == pytest.approx(10.0)

    def test_trace_drift_needs_min_samples(self):
        from repro.comm.perfmodel import TPU_V5E
        from repro.fleet import DriftDetector

        det = DriftDetector(threshold=3.0, min_samples=4)
        rep = det.audit(_decisions(), TPU_V5E, trace=_trace_agg(10.0, count=3))
        assert rep.drifted_count == 0  # 3 samples: outlier, not drift
        assert [f.source for f in rep.findings
                if f.fingerprint == "prog1"] == ["trace"]

    def test_in_band_trace_does_not_drift(self):
        from repro.comm.perfmodel import TPU_V5E
        from repro.fleet import DriftDetector

        rep = DriftDetector(threshold=3.0, min_samples=4).audit(
            _decisions(), TPU_V5E, trace=_trace_agg(1.1)
        )
        assert rep.drifted_count == 0
        prog = [f for f in rep.findings if f.fingerprint == "prog1"][0]
        assert prog.source == "trace"
        # the row without coverage stays interpolated
        ct = [f for f in rep.findings if f.fingerprint == "ct1"][0]
        assert ct.source == "interpolated" and not ct.drifted

    def test_format_1_reports_still_load(self):
        # DRIFT_FORMAT 1 predates the trace source: "params" rows load
        # as "interpolated" and phase_ratios default empty
        from repro.fleet import DriftReport

        old = {
            "format": 1, "system": "s", "threshold": 1.5,
            "min_samples": 3, "term_ratios": {"wire": 1.0},
            "findings": [{
                "fingerprint": "f", "strategy": "rows", "term": "",
                "ratio": 1.0, "drifted": False, "source": "params",
                "recorded_total": 1e-5, "repriced_total": 1e-5,
                "observed_mean": 0.0, "observed_ratio": 0.0,
                "samples": 0, "signature": "vec",
            }],
        }
        rep = DriftReport.from_json(json.dumps(old))
        assert rep.findings[0].source == "interpolated"
        assert rep.findings[0].phase_ratios == {}

    def test_current_report_round_trips_with_phase_ratios(self):
        from repro.comm.perfmodel import TPU_V5E
        from repro.fleet import DriftDetector, DriftReport
        from repro.fleet.drift import DRIFT_FORMAT

        rep = DriftDetector(threshold=3.0, min_samples=4).audit(
            _decisions(), TPU_V5E, trace=_trace_agg(10.0), system="t"
        )
        back = DriftReport.from_json(rep.to_json())
        assert back.to_json() == rep.to_json()
        assert json.loads(rep.to_json())["format"] == DRIFT_FORMAT
        prog = [f for f in back.findings if f.fingerprint == "prog1"][0]
        assert prog.phase_ratios["wire"] == pytest.approx(10.0)

    def test_tracer_aggregates_feed_audit_end_to_end(self):
        # Tracer -> phase_aggregates -> audit: the wiring the smoother's
        # --trace/--drift-report path uses
        from repro.comm.api import Communicator
        from repro.fleet import DriftDetector, predict_program_phases
        from repro.launch.smoother import run_smoother

        tr = Tracer()
        decisions = DecisionCache()
        comm = Communicator(
            axis_name="data", decisions=decisions, tracer=tr
        )
        run_smoother(comm, iters=4, interior=(8, 8, 8), cycle="smooth",
                     halo_steps="auto")
        rep = DriftDetector(min_samples=2).audit(
            decisions, comm.model.params, trace=tr.phase_aggregates()
        )
        prog = [f for f in rep.findings
                if f.strategy.startswith("program/")]
        assert len(prog) == 1
        assert prog[0].source == "trace"
        assert prog[0].phase_ratios  # direct per-term evidence on file
        assert prog[0].samples >= 4


# ===========================================================================
# fleet stats CLI
# ===========================================================================

def test_fleet_stats_cli_renders_persisted_metrics(tmp_path, capsys):
    from repro.fleet.__main__ import main
    from repro.obs.metrics import METRICS_FILENAME

    m = MetricsRegistry()
    m.set_counter("comm.exchanges", 12)
    m.set_gauge("telemetry.ring_occupancy", 0.5)
    m.save(tmp_path / METRICS_FILENAME)
    assert main(["stats", "--store", str(tmp_path), "--json"]) == 0
    out = capsys.readouterr().out
    assert "comm.exchanges" in out and "12" in out
    assert '"gauges"' in out
    # empty store: still exits 0 with an empty table
    assert main(["stats", "--store", str(tmp_path / "empty")]) == 0
