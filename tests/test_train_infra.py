"""Training-infrastructure tests: optimizer, checkpointing (incl. crash
fault model), elastic planning, gradient compression, data determinism."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import smoke_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.train.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.elastic import StragglerMonitor, plan_remesh
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    dequantize_grad_int8,
    global_norm,
    init_opt_state,
    quantize_grad_int8,
)


class TestOptimizer:
    def _toy(self):
        params = {"a": jnp.ones((4, 4), jnp.bfloat16), "norm": jnp.ones((4,))}
        grads = {"a": jnp.full((4, 4), 0.5, jnp.float32),
                 "norm": jnp.full((4,), 0.1, jnp.float32)}
        return params, grads

    def test_step_moves_params(self):
        cfg = AdamWConfig(lr=1e-2, warmup_steps=0)
        params, grads = self._toy()
        st = init_opt_state(params, cfg)
        new, st2, m = adamw_update(params, grads, st, cfg)
        assert st2["step"] == 1
        assert not np.allclose(np.asarray(new["a"], np.float32),
                               np.asarray(params["a"], np.float32))
        assert m["grad_norm"] > 0

    def test_clipping(self):
        cfg = AdamWConfig(clip_norm=1e-3, warmup_steps=0)
        params, grads = self._toy()
        st = init_opt_state(params, cfg)
        _, _, m = adamw_update(params, grads, st, cfg)
        assert float(m["grad_norm"]) > 1e-3  # raw norm reported

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        params, _ = self._toy()
        st = init_opt_state(params, cfg)
        assert st["mu"]["a"].dtype == jnp.bfloat16

    def test_global_norm(self):
        t = {"x": jnp.ones((3,)), "y": jnp.ones((4,))}
        assert float(global_norm(t)) == pytest.approx(np.sqrt(7.0))

    def test_int8_grad_compression_roundtrip(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        q, scale = quantize_grad_int8(g)
        back = dequantize_grad_int8(q, scale)
        err = float(jnp.max(jnp.abs(back - g)))
        assert err <= float(scale) * 0.51  # half-ulp of the int8 grid


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        state = {"params": {"w": np.arange(6.0).reshape(2, 3)},
                 "opt": {"step": np.int32(7)}}
        save_checkpoint(str(tmp_path), 10, state)
        step, got = restore_checkpoint(str(tmp_path))
        assert step == 10
        np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])

    def test_torn_checkpoint_skipped(self, tmp_path):
        """Crash fault model: an incomplete write must not be restored."""
        state = {"w": np.ones(3)}
        save_checkpoint(str(tmp_path), 1, state)
        # simulate a crash mid-write of step 2: directory without manifest
        os.makedirs(tmp_path / "step_00000002")
        (tmp_path / "step_00000002" / "shards.npz").write_bytes(b"garbage")
        assert latest_step(str(tmp_path)) == 1
        step, _ = restore_checkpoint(str(tmp_path))
        assert step == 1

    def test_retention(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, {"w": np.ones(2)}, keep=2)
        kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(kept) == 2 and kept[-1].endswith("05")


class TestElastic:
    def test_remesh_keeps_model_parallel(self):
        plan = plan_remesh(survivors=192, model_parallel=16, global_batch=256)
        assert plan.shape[-1] == 16
        assert plan.shape[0] * 16 <= 192
        assert plan.global_batch <= 256

    def test_remesh_multi_pod(self):
        plan = plan_remesh(512, 16, 256, multi_pod=True)
        assert plan.axes == ("pod", "data", "model")
        plan2 = plan_remesh(300, 16, 256, multi_pod=True)  # lost most of pod 2
        assert plan2.axes == ("data", "model")

    def test_remesh_insufficient(self):
        with pytest.raises(RuntimeError):
            plan_remesh(8, 16, 256)

    def test_straggler_monitor(self):
        mon = StragglerMonitor(threshold=1.5, patience=3)
        assert mon.observe(0, 1.0) == "ok"
        for i in range(5):
            assert mon.observe(1 + i, 1.02) == "ok"
        assert mon.observe(10, 2.5) == "slow"
        assert mon.observe(11, 2.5) == "slow"
        assert mon.observe(12, 2.5) == "remesh"
        # recovery resets the streak
        mon2 = StragglerMonitor(threshold=1.5, patience=2)
        mon2.observe(0, 1.0)
        assert mon2.observe(1, 2.0) == "slow"
        assert mon2.observe(2, 1.0) == "ok"
        assert mon2.observe(3, 2.0) == "slow"


class TestData:
    def test_deterministic_per_step_and_host(self):
        cfg = smoke_config("qwen2-0.5b")
        shape = ShapeConfig("t", 32, 4, "train")
        a = synthetic_batch(cfg, shape, step=3)
        b = synthetic_batch(cfg, shape, step=3)
        c = synthetic_batch(cfg, shape, step=4)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert not np.array_equal(a["tokens"], c["tokens"])
        # hosts see different slices
        h0 = synthetic_batch(cfg, shape, 3, DataConfig(num_hosts=2, host_id=0))
        h1 = synthetic_batch(cfg, shape, 3, DataConfig(num_hosts=2, host_id=1))
        assert h0["tokens"].shape[0] == 2
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_tokens_in_vocab(self):
        cfg = smoke_config("qwen2-0.5b")
        shape = ShapeConfig("t", 64, 2, "train")
        b = synthetic_batch(cfg, shape, 0)
        assert int(jnp.max(b["tokens"])) < cfg.vocab_size
        assert int(jnp.min(b["tokens"])) >= 0


def test_checkpoint_bf16_roundtrip(tmp_path):
    """bf16 leaves survive the npz round-trip bit-exactly (stored as
    uint16 bit patterns + dtype in the manifest)."""
    import jax.numpy as jnp
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.bfloat16)
    save_checkpoint(str(tmp_path), 1, {"w": w, "b": np.float32(2.5)})
    _, got = restore_checkpoint(str(tmp_path))
    assert str(got["w"].dtype) == "bfloat16"
    np.testing.assert_array_equal(
        np.asarray(got["w"]).view(np.uint16), np.asarray(w).view(np.uint16)
    )
