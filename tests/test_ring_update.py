"""ring_update / ring_update_stacked on a real multi-device mesh."""

import pytest

from tests._subproc import run_with_devices

CODE = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.distributed.sharding import DEFAULT_RULES, use_rules
from repro.models.layers import ring_update, ring_update_stacked

mesh = make_mesh((2, 4), ("data", "model"))
B, S, KV, HD = 4, 16, 2, 8
L = 3

with use_rules(mesh, DEFAULT_RULES):
    cache = jnp.zeros((B, S, KV, HD), jnp.bfloat16)
    cache = jax.device_put(cache, NamedSharding(mesh, P("data", "model")))
    new = jnp.ones((B, 1, KV, HD), jnp.bfloat16) * 7

    fn = jax.jit(lambda c, n, s: ring_update(c, n, s))
    for slot in (0, 5, 15):
        out = np.asarray(fn(cache, new, jnp.int32(slot)))
        want = np.zeros((B, S, KV, HD), np.float32)
        want[:, slot] = 7
        np.testing.assert_array_equal(out.astype(np.float32), want)

    # stacked variant
    c2 = jnp.zeros((L, B, S, KV, HD), jnp.bfloat16)
    c2 = jax.device_put(c2, NamedSharding(mesh, P(None, "data", "model")))
    n2 = jnp.arange(L, dtype=jnp.bfloat16)[:, None, None, None, None] * jnp.ones(
        (L, B, 1, KV, HD), jnp.bfloat16)
    out2 = np.asarray(jax.jit(ring_update_stacked)(c2, n2, jnp.int32(9)))
    for l in range(L):
        np.testing.assert_array_equal(
            out2[l, :, 9].astype(np.float32),
            np.full((B, KV, HD), float(l), np.float32))
        assert (out2[l, :, :9] == 0).all() and (out2[l, :, 10:] == 0).all()
print("RING_OK")
"""


@pytest.mark.slow
def test_ring_update_multidevice():
    out = run_with_devices(CODE, ndev=8)
    assert "RING_OK" in out
