"""Chunked linear attention == sequential recurrence (both decays)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.models.linear_attn import (
    LOG_CLAMP,
    chunked_scalar_decay,
    chunked_vector_decay,
    step_scalar_decay,
    step_vector_decay,
)

RNG = np.random.default_rng(42)


def _rand(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32)) * 0.3


@pytest.mark.parametrize("S,chunk", [(64, 16), (96, 32), (128, 128), (32, 64)])
def test_scalar_matches_sequential(S, chunk):
    B, H, dk, dv = 2, 3, 8, 16
    q, k = _rand(B, S, H, dk), _rand(B, S, H, dk)
    v = _rand(B, S, H, dv)
    ld = -jnp.abs(_rand(B, S, H)) * 0.5
    y, st = chunked_scalar_decay(q, k, v, ld, chunk=chunk)

    # sequential oracle via the decode step
    state = jnp.zeros((B, H, dk, dv), jnp.float32)
    ys = []
    for t in range(S):
        yt, state = step_scalar_decay(q[:, t], k[:, t], v[:, t], ld[:, t], state)
        ys.append(yt)
    want = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("S,chunk", [(64, 16), (96, 32), (64, 64)])
def test_vector_matches_sequential(S, chunk):
    B, H, dk, dv = 2, 2, 8, 8
    q, k = _rand(B, S, H, dk), _rand(B, S, H, dk)
    v = _rand(B, S, H, dv)
    # decays within the clamp so both paths are exact
    ld = -jnp.abs(_rand(B, S, H, dk)) * (LOG_CLAMP * 0.8)
    u = _rand(H, dk)
    y, st = chunked_vector_decay(q, k, v, ld, u, chunk=chunk)

    state = jnp.zeros((B, H, dk, dv), jnp.float32)
    ys = []
    for t in range(S):
        yt, state = step_vector_decay(q[:, t], k[:, t], v[:, t], ld[:, t], u, state)
        ys.append(yt)
    want = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state), rtol=3e-4, atol=3e-4)


def test_state_carry_across_calls():
    """Splitting a sequence across two chunked calls == one call."""
    B, S, H, dk, dv = 1, 64, 2, 8, 8
    q, k, v = _rand(B, S, H, dk), _rand(B, S, H, dk), _rand(B, S, H, dv)
    ld = -jnp.abs(_rand(B, S, H)) * 0.4
    y_full, st_full = chunked_scalar_decay(q, k, v, ld, chunk=16)
    y1, st1 = chunked_scalar_decay(
        q[:, :32], k[:, :32], v[:, :32], ld[:, :32], chunk=16
    )
    y2, st2 = chunked_scalar_decay(
        q[:, 32:], k[:, 32:], v[:, 32:], ld[:, 32:], state0=st1, chunk=16
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)),
        np.asarray(y_full),
        rtol=2e-4,
        atol=2e-4,
    )
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), rtol=2e-4, atol=2e-4)
