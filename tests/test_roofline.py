"""Validate the loop-aware HLO cost parser against controlled programs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.roofline.hlo_cost import cost_analysis_dict, parse_hlo_cost
from repro.roofline.analysis import collective_bytes


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_trip_count_correction():
    """scanned matmuls must cost ~the same as unrolled ones."""
    D, L = 128, 12
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)

    def scanned(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    def unrolled(x, ws):
        for i in range(L):
            x = x @ ws[i]
        return x

    cs = parse_hlo_cost(_compile(scanned, x, ws).as_text())
    cu = parse_hlo_cost(_compile(unrolled, x, ws).as_text())
    analytic = 2.0 * D**3 * L
    assert cs.flops == pytest.approx(analytic, rel=0.25), cs.flops
    assert cu.flops == pytest.approx(analytic, rel=0.25), cu.flops
    # and the builtin cost_analysis is indeed trip-blind (the reason this
    # module exists) — if XLA ever fixes it, we can drop the parser
    builtin = cost_analysis_dict(_compile(scanned, x, ws))["flops"]
    assert builtin < 0.5 * analytic


def test_dot_flops_with_batch_dims():
    B, M, K, N = 4, 32, 64, 16
    a = jax.ShapeDtypeStruct((B, M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((B, K, N), jnp.float32)
    c = parse_hlo_cost(_compile(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b), a, b).as_text())
    assert c.flops == pytest.approx(2 * B * M * K * N, rel=0.2)


def test_nested_scan_multiplies():
    D, L1, L2 = 64, 5, 7
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L1, L2, D, D), jnp.float32)

    def fn(x, ws):
        def outer(c, wrow):
            return lax.scan(lambda cc, w: (cc @ w, None), c, wrow)[0], None
        return lax.scan(outer, x, ws)[0]

    c = parse_hlo_cost(_compile(fn, x, ws).as_text())
    assert c.flops == pytest.approx(2 * D**3 * L1 * L2, rel=0.25)


def test_collectives_inside_loops_counted():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under subprocess runner)")


def test_dynamic_slice_counts_slice_not_operand():
    """A scan reading 1-row slices of a big array must cost ~L x slice
    bytes, not L x full-array bytes."""
    L, D = 64, 256
    big = jax.ShapeDtypeStruct((L, D), jnp.float32)

    def fn(ws):
        def body(c, _):
            i = c[0].astype(jnp.int32)
            row = lax.dynamic_slice(ws, (i, 0), (1, D))
            return (c[0] + 1, c[1] + row.sum()), None

        return lax.scan(body, (jnp.float32(0), jnp.float32(0)), None, length=L)[0]

    c = parse_hlo_cost(_compile(fn, big).as_text())
    slice_traffic = L * D * 4 * 2
    full_traffic = L * L * D * 4
    assert c.bytes < 0.5 * full_traffic, (c.bytes, full_traffic)
    assert c.bytes >= slice_traffic * 0.5


def test_collective_bytes_regex_forms():
    hlo = """
ENTRY %main () -> f32[] {
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag = bf16[8,256]{1,0} all-gather(bf16[8,16]{1,0} %y), dimensions={1}
  %cp = f32[512]{0} collective-permute(f32[512]{0} %z)
}
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 1024 * 4 * 2.0
    assert got["all-gather"] == 8 * 256 * 2
    assert got["collective-permute"] == 512 * 4
