"""Per-kernel allclose tests against the pure-jnp oracle (ref.py).

Sweeps shapes/dtypes per the deliverable: every Pallas kernel variant
(rows, dma) plus the XLA-blocks baseline is compared bit-exactly with the
gather oracle across 2D/3D strided blocks, word widths, offsets, and
incounts.  Kernels run in interpret mode on CPU.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BYTE,
    FLOAT,
    FLOAT16,
    INT16,
    INT32,
    Contiguous,
    Hvector,
    Subarray,
    TypeRegistry,
    Vector,
)
from repro.kernels import pack, plan_geometry, unpack
from repro.kernels.geometry import VMEM_BUDGET_BYTES
from repro.kernels.ops import byte_view
from repro.kernels.ref import pack_ref, unpack_ref

REG = TypeRegistry()
RNG = np.random.default_rng(1234)

KERNEL_STRATEGIES = ("rows", "dma")
ALL_STRATEGIES = ("rows", "dma", "xla", "auto")


def rand_bytes(n):
    return jnp.asarray(RNG.integers(0, 255, size=(n,), dtype=np.uint8))


def check_roundtrip(dt, strategies=ALL_STRATEGIES, incount=1):
    ct = REG.commit(dt)
    need = ct.extent * incount
    buf = rand_bytes(need + 37)  # ragged tail on purpose
    want = np.asarray(pack_ref(buf, ct.block, incount, ct.extent))
    dst0 = rand_bytes(need + 37)
    want_dst = np.asarray(unpack_ref(dst0, jnp.asarray(want), ct.block, incount, ct.extent))
    for strat in strategies:
        got = pack(buf, ct, incount=incount, strategy=strat)
        assert got.shape == (ct.size * incount,)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=f"pack:{strat}")
        out = unpack(dst0, got, ct, incount=incount, strategy=strat)
        np.testing.assert_array_equal(
            np.asarray(out), want_dst, err_msg=f"unpack:{strat}"
        )


# ---------------------------------------------------------------------------
# 2D sweeps (paper Fig. 7: vector/subarray objects, 512B pitch)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("blocklen_bytes", [8, 32, 100, 128, 512])
@pytest.mark.parametrize("count", [1, 2, 13, 64])
def test_pack_2d_vector_sweep(blocklen_bytes, count):
    pitch = max(512, blocklen_bytes)
    if blocklen_bytes == pitch:
        pytest.skip("fully contiguous: covered by contig test")
    check_roundtrip(Vector(count, blocklen_bytes, pitch, BYTE))


@pytest.mark.parametrize("named", [BYTE, INT16, FLOAT, FLOAT16, INT32])
def test_pack_2d_dtype_sweep(named):
    w = named.extent
    check_roundtrip(Vector(24, 96 // w, 640 // w, named))


@pytest.mark.parametrize("start", [0, 1, 3, 64, 129])
def test_pack_2d_offsets(start):
    # offsets come from subarray starts; misaligned starts force W=1
    check_roundtrip(Subarray((256, 40), (100, 24), (start, 7), BYTE))


def test_planner_rejects_straddle_and_bad_plane_stride():
    from repro.core.strided_block import StridedBlock

    # block straddles a pitch row: r + lanes > pitch
    assert plan_geometry(StridedBlock(200, (100, 5), (1, 256))) is None
    # plane stride not a whole number of pitches
    assert plan_geometry(StridedBlock(0, (8, 4, 2), (1, 32, 100))) is None
    # well-formed constructors can never produce a straddle: subarray
    # guarantees start0 + sub0 <= size0 and hvector guarantees
    # stride >= blocklength, so the aligned planner covers the whole
    # constructor subset (checked exhaustively by the property test).


# ---------------------------------------------------------------------------
# 3D sweeps (paper Fig. 1 cuboids)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "alloc,ext,starts",
    [
        ((64, 32, 16), (40, 13, 7), (8, 3, 2)),
        ((256, 8, 4), (100, 8, 4), (0, 0, 0)),   # full inner dims fold
        ((128, 16, 8), (128, 5, 3), (0, 2, 1)),  # dense rows fold to 2D
        ((512, 4, 4), (12, 3, 2), (64, 1, 1)),
        ((32, 32, 32), (4, 32, 32), (28, 0, 0)),
    ],
)
def test_pack_3d_subarray_sweep(alloc, ext, starts):
    check_roundtrip(Subarray(alloc, ext, starts, BYTE))


@pytest.mark.parametrize("named", [BYTE, FLOAT])
def test_pack_3d_halo_faces(named):
    """The 26-neighbor halo regions of the §6.4 stencil are subarrays of
    these shapes (radius-2 faces/edges/corners of a 32^3 block)."""
    n, r = 32, 2
    e = named.extent
    alloc = (n * e, n, n) if named is BYTE else (n, n, n)
    face = Subarray(alloc, (r if named is BYTE else r, n, n), (0, 0, 0), named)
    edge = Subarray(alloc, (r, r, n), (4, 4, 0), named)
    corner = Subarray(alloc, (r, r, r), (n - r, n - r, n - r), named)
    for dt in (face, edge, corner):
        check_roundtrip(dt)


@pytest.mark.parametrize("incount", [1, 2, 3])
def test_incount(incount):
    check_roundtrip(Vector(6, 20, 50, BYTE), incount=incount)
    check_roundtrip(
        Subarray((64, 8, 4), (16, 4, 2), (4, 1, 1), BYTE),
        strategies=("rows", "dma", "auto"),
        incount=incount,
    )


def test_contig_and_1d():
    check_roundtrip(Contiguous(1000, FLOAT), strategies=("auto",))
    check_roundtrip(Subarray((4096,), (100,), (30,), BYTE), strategies=("auto",))


def test_user_dtype_buffers():
    """pack accepts arbitrarily-shaped/typed user arrays (byte view)."""
    ct = REG.commit(Vector(8, 16, 48, FLOAT))
    buf = jnp.asarray(RNG.normal(size=(64, 64)).astype(np.float32))
    got = pack(buf, ct)
    want = np.asarray(pack_ref(byte_view(buf), ct.block))
    np.testing.assert_array_equal(np.asarray(got), want)
    out = unpack(jnp.zeros((64, 64), jnp.float32), got, ct)
    assert out.shape == (64, 64) and out.dtype == jnp.float32


def test_geometry_planner_properties():
    ct = REG.commit(Vector(13, 25, 128, FLOAT))
    g = plan_geometry(ct.block)
    assert g.word_bytes == 4
    assert g.lanes == 25 and g.pitch == 128
    assert g.rows == 13 and g.planes == 1
    assert g.rows % g.group == 0
    assert g.group * g.pitch * g.word_bytes <= VMEM_BUDGET_BYTES
    assert g.overfetch == pytest.approx(128 / 25)


# ---------------------------------------------------------------------------
# hypothesis: random strided geometry, kernels == oracle
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:  # keep the deterministic tests above collectable
    _HAS_HYPOTHESIS = False


if _HAS_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(1, 3),  # ndims - but at least 2D via min sizes below
        st.data(),
    )
    def test_property_random_subarray_roundtrip(nd, data):
        sizes, subsizes, starts = [], [], []
        for d in range(nd):
            hi = 48 if d == 0 else 8
            size = data.draw(st.integers(2, hi), label=f"size{d}")
            sub = data.draw(st.integers(1, size), label=f"sub{d}")
            start = data.draw(st.integers(0, size - sub), label=f"start{d}")
            sizes.append(size)
            subsizes.append(sub)
            starts.append(start)
        dt = Subarray(tuple(sizes), tuple(subsizes), tuple(starts), BYTE)
        check_roundtrip(dt, strategies=("auto",))

else:

    def test_property_random_subarray_roundtrip():
        pytest.importorskip("hypothesis")
