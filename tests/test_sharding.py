"""Unit tests for the logical-axis sharding rules."""

import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    param_logical_axes,
    param_partition_spec,
)


def fake_mesh():
    """Axis-name-only stand-in (resolve only reads names + shape)."""
    class M:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
    return M()


def fake_mesh_pod():
    class M:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 2}
    return M()


class TestResolve:
    def test_missing_axes_dropped(self):
        m = fake_mesh()
        assert DEFAULT_RULES.resolve("batch", m) == "data"  # pod absent
        mp = fake_mesh_pod()
        assert DEFAULT_RULES.resolve("batch", mp) == ("pod", "data")

    def test_divisibility_fallback(self):
        m = fake_mesh()
        # batch of 1 cannot shard over data=4 -> replicated
        assert DEFAULT_RULES.resolve("batch", m, dim=1) is None
        assert DEFAULT_RULES.resolve("batch", m, dim=8) == "data"
        # multi-axis: drop trailing axes until it divides
        mp = fake_mesh_pod()
        assert DEFAULT_RULES.resolve("batch", mp, dim=2) == "pod"

    def test_none_logical(self):
        assert DEFAULT_RULES.resolve(None, fake_mesh()) is None


class TestParamRules:
    def test_attention_weights(self):
        assert param_logical_axes("layers/attn/wq", 2) == ("fsdp", "heads")
        assert param_logical_axes("layers/attn/wo", 2) == ("heads", "fsdp")
        # stacked leading layer dim replicated
        assert param_logical_axes("layers/attn/wq", 3) == (None, "fsdp", "heads")

    def test_norms_and_biases_replicated(self):
        assert param_logical_axes("layers/attn/norm", 1) == (None,)
        assert param_logical_axes("layers/attn/bias_q", 1) == (None,)
        assert param_logical_axes("layers/rwkv/mu_r", 1) == (None,)
        assert param_logical_axes("layers/rwkv/ln_x", 1) == (None,)

    def test_moe_experts(self):
        assert param_logical_axes("layers/moe/w_in", 4) == (
            None, "expert", "fsdp", "d_ff")

    def test_rwkv_channel_mix(self):
        assert param_logical_axes("layers/rwkv/cv", 2) == ("d_ff", "fsdp")
        assert param_logical_axes("layers/rwkv/wr", 2) == ("fsdp", "heads")

    def test_embed_and_head(self):
        assert param_logical_axes("embed/vocab", 2) == ("vocab", "fsdp")
        assert param_logical_axes("lm_head", 2) == ("fsdp", "vocab")

    def test_spec_respects_shape(self):
        m = fake_mesh()
        # kv-head projection whose out dim doesn't divide model axis
        spec = param_partition_spec("layers/attn/wk", 2, DEFAULT_RULES, m,
                                    shape=(64, 3))
        assert spec == P(None, None)
        spec2 = param_partition_spec("layers/attn/wk", 2, DEFAULT_RULES, m,
                                     shape=(64, 4))
        assert spec2 == P(None, "model")
