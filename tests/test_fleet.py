"""Fleet layer: telemetry aggregates, drift detection + targeted
re-measurement, and decision-bundle rollout semantics."""

import dataclasses
import json

import pytest

from repro.comm.perfmodel import SystemParams, TPU_V5E
from repro.fleet import (
    BUNDLE_FORMAT,
    CONFLICT_POLICIES,
    DecisionBundle,
    DriftDetector,
    DriftReport,
    ExchangeTelemetry,
    RingAggregate,
    diff_bundles,
    load_bundle,
    merge_bundles,
    promote,
    remeasure_term,
    rollback,
)
from repro.fleet.drift import TERMS
from repro.measure.decisions import Decision, DecisionCache

from _subproc import run_with_devices


# ===========================================================================
# telemetry
# ===========================================================================

class TestRingAggregate:
    def test_window_is_bounded_but_lifetime_count_is_not(self):
        agg = RingAggregate("k", predicted=1e-4, capacity=4)
        for i in range(10):
            agg.observe(float(i))
        assert agg.count == 4          # window holds the newest 4
        assert agg.total_count == 10   # lifetime tally keeps going
        # ring overwrote 0..5; the window is {6,7,8,9} in some order
        assert agg.mean == pytest.approx((6 + 7 + 8 + 9) / 4)

    def test_p95_is_window_order_statistic(self):
        agg = RingAggregate("k", capacity=100)
        for i in range(100):
            agg.observe(float(i))
        assert agg.p95 == 94.0

    def test_ratio_needs_prediction_and_samples(self):
        agg = RingAggregate("k", predicted=0.0)
        assert agg.ratio is None       # no prediction
        agg.predicted = 2.0
        assert agg.ratio is None       # no samples
        agg.observe(3.0)
        assert agg.ratio == pytest.approx(1.5)


class TestExchangeTelemetry:
    def test_register_then_observe_joins_by_key(self):
        tel = ExchangeTelemetry()
        tel.register("fp", 1e-4, "wire/grouped")
        tel.observe("fp", 2e-4)
        agg = tel.get("fp")
        assert agg.strategy == "wire/grouped"
        assert agg.ratio == pytest.approx(2.0)
        # re-registering updates the prediction without dropping samples
        tel.register("fp", 4e-4)
        assert tel.get("fp").count == 1
        assert tel.get("fp").ratio == pytest.approx(0.5)

    def test_save_load_round_trip(self, tmp_path):
        tel = ExchangeTelemetry(capacity=8)
        tel.register("a", 1e-5, "rows")
        for _ in range(3):
            tel.observe("a", 2e-5)
        tel.observe("b", 5e-5)
        p = tel.save(tmp_path / "telemetry.json")
        back = ExchangeTelemetry.load(p)
        assert len(back) == 2
        assert back.get("a").count == 3
        assert back.get("a").ratio == pytest.approx(2.0)
        assert back.get("a").strategy == "rows"
        # absent file -> empty registry (cold start)
        assert len(ExchangeTelemetry.load(tmp_path / "nope.json")) == 0

    def test_format_mismatch_refused(self, tmp_path):
        p = tmp_path / "telemetry.json"
        p.write_text(json.dumps({"format": 999, "aggregates": []}))
        with pytest.raises(ValueError, match="format"):
            ExchangeTelemetry.load(p)

    def test_report_shows_observed_vs_predicted(self):
        tel = ExchangeTelemetry()
        tel.register("deadbeef", 1e-4, "program/s=2")
        tel.observe("deadbeef", 1.2e-4)
        rep = tel.report()
        assert "obs/pred" in rep and "deadbeef" in rep
        assert "1.200" in rep

    def test_timed_context_observes(self):
        tel = ExchangeTelemetry()
        with tel.timed("k", predicted=1.0):
            pass
        assert tel.get("k").count == 1
        assert tel.get("k").mean >= 0.0


def test_communicator_eager_sendrecv_feeds_telemetry(monkeypatch):
    # an eager (non-traced) blocking exchange is timed; the key is the
    # committed type's content fingerprint, and a prediction is on file
    # from the isend planning half.  JAX has no eager evaluation rule
    # for collectives, so the wire op is stubbed to a self-send — the
    # pack/unpack halves and the probe run for real.
    import jax.numpy as jnp

    from repro.comm import api
    from repro.core import BYTE, Vector

    monkeypatch.setattr(api.lax, "ppermute", lambda x, axis, perm: x)
    tel = ExchangeTelemetry()
    comm = api.Communicator(axis_name="x", telemetry=tel)
    ct = comm.commit(Vector(4, 8, 16, BYTE))
    buf = jnp.arange(ct.extent, dtype=jnp.uint8)
    out = comm.sendrecv(buf, jnp.zeros_like(buf), ct, [(0, 0)])
    assert out.shape == buf.shape
    agg = tel.get(ct.fingerprint)
    assert agg is not None and agg.count == 1
    assert agg.predicted > 0.0
    assert comm.stats()["telemetry_keys"] >= 1


def test_communicator_plan_neighbor_registers_prediction():
    # the trace-time half: planning a fused exchange puts the wire
    # plan's predicted seconds on file under the plan fingerprint
    from repro.comm.api import Communicator
    from repro.core import BYTE, Vector

    tel = ExchangeTelemetry()
    comm = Communicator(axis_name="x", telemetry=tel,
                        decisions=DecisionCache())
    ct = comm.commit(Vector(4, 8, 16, BYTE))
    _, plan = comm.plan_neighbor([ct], [((0, 0),)])
    agg = tel.get(plan.fingerprint)
    assert agg is not None
    assert agg.predicted > 0.0
    assert agg.strategy.startswith("wire/")
    # the same key exists in the decision cache: telemetry rows join
    # decision rows by fingerprint
    assert any(
        d.fingerprint == plan.fingerprint for d in comm.model.decisions.log
    )


# ===========================================================================
# drift
# ===========================================================================

def _reference_params() -> SystemParams:
    return dataclasses.replace(
        TPU_V5E,
        name="ref",
        wire_table=((10.0, 1e-5), (14.0, 2e-5), (18.0, 9e-5)),
        stencil_table=((2.58, 10.0, 5e-6), (2.58, 14.0, 2e-5)),
        copy_table=((10.0, 1e-6), (14.0, 4e-6)),
        pack_table={"rows": ((3.0, 10.0, 2e-6), (3.0, 14.0, 8e-6))},
        unpack_table={"rows": ((3.0, 10.0, 3e-6), (3.0, 14.0, 9e-6))},
    )


def _decisions() -> DecisionCache:
    return DecisionCache([
        Decision("wplan1", 2, 3, True, "wire/grouped", 0.0, 3e-4, 0.0,
                 "exchange", 4096),
        Decision("prog1", 0, 1, True, "program/s=2", 1e-5, 3e-5, 0.0,
                 "deep halo", 2048),
        Decision("ct1", 1, 1, True, "rows", 2e-6, 1e-5, 3e-6, "vec", 1024),
    ])


class TestDriftDetector:
    def test_identical_params_no_drift(self):
        ref = _reference_params()
        rep = DriftDetector(threshold=2.0).audit(
            _decisions(), ref, reference=ref
        )
        assert rep.drifted_count == 0
        assert all(r == pytest.approx(1.0) for r in rep.term_ratios.values())

    def test_perturbed_wire_flags_exactly_the_wire_term(self):
        ref = _reference_params()
        live = dataclasses.replace(
            ref, wire_table=tuple((x, 10 * s) for x, s in ref.wire_table)
        )
        rep = DriftDetector(threshold=3.0).audit(
            _decisions(), live, reference=ref
        )
        # every strategy class prices through the wire -> all drift,
        # all attributed to the wire term
        assert rep.drifted_count == 3
        assert rep.drifted_terms == ("wire",)

    def test_perturbed_stencil_flags_only_the_program_row(self):
        ref = _reference_params()
        live = dataclasses.replace(
            ref,
            stencil_table=tuple(
                (a, b, 8 * s) for a, b, s in ref.stencil_table
            ),
        )
        rep = DriftDetector(threshold=3.0).audit(
            _decisions(), live, reference=ref
        )
        assert [(f.strategy, f.term) for f in rep.drifted] == [
            ("program/s=2", "stencil")
        ]

    def test_perturbed_pack_flags_only_the_strategy_row(self):
        ref = _reference_params()
        live = dataclasses.replace(
            ref,
            pack_table={
                "rows": tuple((a, b, 20 * s) for a, b, s in
                              ref.pack_table["rows"])
            },
        )
        rep = DriftDetector(threshold=3.0).audit(
            _decisions(), live, reference=ref
        )
        assert [(f.strategy, f.term) for f in rep.drifted] == [
            ("rows", "pack_unpack")
        ]

    def test_targeted_remeasure_clears_the_flag(self):
        ref = _reference_params()
        live = dataclasses.replace(
            ref, wire_table=tuple((x, 10 * s) for x, s in ref.wire_table)
        )
        det = DriftDetector(threshold=3.0)
        assert det.audit(_decisions(), live, reference=ref).drifted_count == 3
        fixed = remeasure_term(
            live, "wire", measured={"wire_table": ref.wire_table}
        )
        assert det.audit(_decisions(), fixed, reference=ref).drifted_count == 0
        # ...and only the wire table moved
        assert fixed.stencil_table == live.stencil_table
        assert fixed.pack_table == live.pack_table

    def test_remeasure_rejects_unknown_term(self):
        with pytest.raises(ValueError, match="unknown term"):
            remeasure_term(_reference_params(), "latency")
        assert set(TERMS) == {
            "wire", "pack_unpack", "stencil", "copy", "compress"
        }

    def test_telemetry_drift_needs_min_samples(self):
        ref = _reference_params()
        tel = ExchangeTelemetry()
        dc = _decisions()
        tel.register("ct1", dc.log[2].total, "rows")
        det = DriftDetector(threshold=3.0, min_samples=4)
        for _ in range(3):
            tel.observe("ct1", 100 * dc.log[2].total)
        rep = det.audit(dc, ref, reference=ref, telemetry=tel)
        assert rep.drifted_count == 0  # 3 < min_samples: outliers, not drift
        tel.observe("ct1", 100 * dc.log[2].total)
        rep = det.audit(dc, ref, reference=ref, telemetry=tel)
        drifted = rep.drifted
        assert len(drifted) == 1
        assert drifted[0].fingerprint == "ct1"
        assert drifted[0].source == "telemetry"
        assert drifted[0].samples == 4

    def test_report_json_round_trips(self):
        ref = _reference_params()
        live = dataclasses.replace(
            ref, wire_table=tuple((x, 10 * s) for x, s in ref.wire_table)
        )
        rep = DriftDetector(threshold=3.0).audit(
            _decisions(), live, reference=ref, system="t"
        )
        back = DriftReport.from_json(rep.to_json())
        assert back.to_json() == rep.to_json()
        assert back.drifted_count == rep.drifted_count

    def test_threshold_must_exceed_one(self):
        with pytest.raises(ValueError):
            DriftDetector(threshold=1.0)


def test_predict_program_iteration_includes_interior_compute():
    # the launch-layer prediction = the model's exchange+redundant
    # estimate plus the interior stencil compute price_program excludes
    from repro.comm.api import Communicator
    from repro.fleet import predict_program_iteration
    from repro.halo.program import build_halo_program

    comm = Communicator(axis_name="data", decisions=DecisionCache())
    program = build_halo_program((1, 1, 1), (8, 8, 8), comm, steps=2)
    t = predict_program_iteration(program, comm.model)
    assert t > program.estimate.total


# ===========================================================================
# bundles
# ===========================================================================

def _row(fp, strategy="rows", total=1e-5, hops=1):
    return Decision(fp, 1, hops, True, strategy, total / 2, total / 4,
                    total / 4, f"sig-{fp}", 64)


class TestBundleEnvelope:
    def test_json_round_trip_and_canonical_order(self):
        b = DecisionBundle(
            DecisionCache([_row("b"), _row("a")]),
            generation=3, system="sysfp", host="h1",
        )
        back = DecisionBundle.from_json(b.to_json())
        assert back.generation == 3 and back.system == "sysfp"
        assert len(back.decisions) == 2
        # canonical: rows key-sorted regardless of recording order
        b2 = DecisionBundle(
            DecisionCache([_row("a"), _row("b")]),
            generation=3, system="sysfp", host="h1",
        )
        assert b.to_json() == b2.to_json()

    def test_format_mismatch_refused(self):
        d = json.loads(
            DecisionBundle(DecisionCache([_row("a")])).to_json()
        )
        d["bundle_format"] = 99
        with pytest.raises(ValueError, match="bundle format"):
            DecisionBundle.from_json(json.dumps(d))
        assert BUNDLE_FORMAT == 1

    def test_load_bundle_auto_wraps_raw_decisions_file(self, tmp_path):
        dc = DecisionCache([_row("x")])
        p = dc.save(tmp_path / "decisions.json")
        b = load_bundle(p)
        assert b.generation == 0
        assert len(b.decisions) == 1
        # a real bundle file loads with its provenance intact
        bp = DecisionBundle(dc, generation=7).save(tmp_path / "b.json")
        assert load_bundle(bp).generation == 7


class TestMerge:
    def test_disjoint_merge_is_union(self):
        a = DecisionBundle(DecisionCache([_row("a")]), generation=1)
        b = DecisionBundle(DecisionCache([_row("b")]), generation=2)
        m = merge_bundles([a, b])
        assert len(m.decisions) == 2
        assert m.generation == 3  # max(input)+1: a merge is a rollout

    @pytest.mark.parametrize("policy", CONFLICT_POLICIES)
    def test_merge_commutative_and_deterministic(self, policy):
        shared_old = _row("s", strategy="rows", total=1e-5)
        shared_new = _row("s", strategy="dma", total=2e-5)
        a = DecisionBundle(
            DecisionCache([shared_old, _row("a")]), generation=1, host="a"
        )
        b = DecisionBundle(
            DecisionCache([shared_new, _row("b")]), generation=2, host="b"
        )
        m1 = merge_bundles([a, b], policy=policy)
        m2 = merge_bundles([b, a], policy=policy)
        assert m1.to_json() == m2.to_json()

    def test_newest_generation_policy_prefers_newer_row(self):
        old = _row("s", strategy="rows", total=1e-6)   # cheaper but older
        new = _row("s", strategy="dma", total=2e-5)
        a = DecisionBundle(DecisionCache([old]), generation=1)
        b = DecisionBundle(DecisionCache([new]), generation=5)
        m = merge_bundles([a, b], policy="newest-generation")
        assert m.decisions.log[0].strategy == "dma"

    def test_lowest_price_policy_prefers_cheaper_row(self):
        old = _row("s", strategy="rows", total=1e-6)
        new = _row("s", strategy="dma", total=2e-5)
        a = DecisionBundle(DecisionCache([old]), generation=1)
        b = DecisionBundle(DecisionCache([new]), generation=5)
        m = merge_bundles([a, b], policy="lowest-price")
        assert m.decisions.log[0].strategy == "rows"

    def test_unknown_policy_refused(self):
        a = DecisionBundle(DecisionCache([_row("a")]))
        with pytest.raises(ValueError, match="conflict policy"):
            merge_bundles([a], policy="coin-flip")

    def test_provenance_carries_only_when_unanimous(self):
        a = DecisionBundle(DecisionCache([_row("a")]), system="s1")
        b = DecisionBundle(DecisionCache([_row("b")]), system="s1")
        assert merge_bundles([a, b]).system == "s1"
        c = DecisionBundle(DecisionCache([_row("c")]), system="s2")
        assert merge_bundles([a, c]).system == ""  # cross-system: no lie


class TestDiffPromote:
    def test_diff_round_trips_byte_identically(self):
        a = DecisionBundle(
            DecisionCache([_row("a"), _row("s", strategy="rows")]),
            generation=1,
        )
        b = DecisionBundle(
            DecisionCache([_row("b"), _row("s", strategy="dma")]),
            generation=2,
        )
        d = diff_bundles(a, b)
        s1 = json.dumps(d, sort_keys=True, indent=2)
        s2 = json.dumps(json.loads(s1), sort_keys=True, indent=2)
        assert s1 == s2
        assert len(d["added"]) == 1 and len(d["removed"]) == 1
        assert len(d["changed"]) == 1
        assert d["changed"][0]["before"]["strategy"] == "rows"

    def test_promote_and_rollback(self, tmp_path):
        live = tmp_path / "decisions.json"
        DecisionCache([_row("old")]).save(live)
        staged = DecisionBundle(
            DecisionCache([_row("new")]), generation=2
        )
        installed, backup = promote(staged, live)
        # the live file is raw engine-loadable decisions JSON
        assert DecisionCache.load(installed).log[0].fingerprint == "new"
        assert backup is not None and backup.exists()
        # provenance survives next to the live file
        assert load_bundle(live.with_name(live.name + ".bundle")).generation == 2
        rollback(live)
        assert DecisionCache.load(live).log[0].fingerprint == "old"

    def test_rollback_without_promote_refused(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            rollback(tmp_path / "decisions.json")


# ===========================================================================
# CLI
# ===========================================================================

class TestCli:
    def test_merge_diff_promote_cli(self, tmp_path, capsys):
        from repro.fleet.__main__ import main

        a = DecisionBundle(DecisionCache([_row("a")]), generation=1)
        b = DecisionBundle(DecisionCache([_row("b")]), generation=2)
        pa, pb = a.save(tmp_path / "a.json"), b.save(tmp_path / "b.json")
        out = tmp_path / "merged.json"
        assert main(["merge", str(pa), str(pb), "--out", str(out)]) == 0
        assert len(load_bundle(out).decisions) == 2
        assert main(["diff", str(pa), str(pb)]) == 0
        assert main(["diff", str(pa), str(pa), "--assert-same"]) == 0
        assert main(["diff", str(pa), str(pb), "--assert-same"]) == 1
        live = tmp_path / "live.json"
        assert main(["promote", str(out), "--live", str(live)]) == 0
        assert len(DecisionCache.load(live)) == 2
        capsys.readouterr()

    def test_report_cli_renders_ratios(self, tmp_path, capsys):
        from repro.fleet.__main__ import main

        tel = ExchangeTelemetry()
        tel.register("fp1", 1e-4, "wire/grouped")
        tel.observe("fp1", 2e-4)
        tel.save(tmp_path / "telemetry.json")
        DecisionCache(
            [_row("fp1", strategy="wire/grouped")]
        ).save(tmp_path / "decisions.json")
        assert main(["report", "--store", str(tmp_path)]) == 0
        out = capsys.readouterr().out
        assert "obs/pred" in out and "2.000" in out


# ===========================================================================
# cross-process merge (two hosts record, results merge deterministically)
# ===========================================================================

_RECORD_CODE = """
import os
from repro.comm import PerfModel
from repro.core import BYTE, TypeRegistry, Vector
from repro.fleet import DecisionBundle
from repro.measure import DecisionCache, load_ci_params

dts = {dts}
reg = TypeRegistry()
dc = DecisionCache()
model = PerfModel(load_ci_params(), decisions=dc)
for n, b, s in dts:
    model.select(reg.commit(Vector(n, b, s, BYTE)))
DecisionBundle(dc, generation={gen}, host="{host}").save(r"{out}")
print(len(dc))
"""


class TestCrossProcessMerge:
    def _record(self, tmp_path):
        # two processes record overlapping decision sets: the vector
        # (16, 64, 512) is shared, the others are disjoint
        pa = tmp_path / "host_a.json"
        pb = tmp_path / "host_b.json"
        run_with_devices(
            _RECORD_CODE.format(
                dts=[(16, 64, 512), (4096, 8, 4096)], gen=1, host="a",
                out=pa,
            ),
            ndev=1,
        )
        run_with_devices(
            _RECORD_CODE.format(
                dts=[(16, 64, 512), (4, 256, 512)], gen=2, host="b",
                out=pb,
            ),
            ndev=1,
        )
        return load_bundle(pa), load_bundle(pb)

    @pytest.mark.parametrize("policy", CONFLICT_POLICIES)
    def test_two_host_bundles_merge_deterministically(self, tmp_path, policy):
        a, b = self._record(tmp_path)
        assert len(a.decisions) == 2 and len(b.decisions) == 2
        m1 = merge_bundles([a, b], policy=policy)
        m2 = merge_bundles([b, a], policy=policy)
        assert m1.to_json() == m2.to_json()
        # overlap dedupes: 2 + 2 with one shared key -> 3 rows
        assert len(m1.decisions) == 3
        # the shared vector type produced the same fingerprint on both
        # hosts — exactly one key overlaps
        shared = {d.key for d in a.decisions.log} & {
            d.key for d in b.decisions.log
        }
        assert len(shared) == 1

    def test_diff_of_host_bundles_round_trips(self, tmp_path):
        a, b = self._record(tmp_path)
        d = diff_bundles(a, b)
        s1 = json.dumps(d, sort_keys=True, indent=2)
        s2 = json.dumps(json.loads(s1), sort_keys=True, indent=2)
        assert s1 == s2
        assert len(d["added"]) == 1 and len(d["removed"]) == 1
