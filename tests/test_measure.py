"""Unit tests: the repro.measure subsystem — fingerprints, the bench
harness, the params store, the decision cache, and the measured-term
rewiring of the PerfModel (ISSUE 2 acceptance criteria)."""

import json
import os

import numpy as np
import pytest

from repro.comm import PerfModel, SystemParams, TPU_V5E
from repro.comm.perfmodel import _interp2d
from repro.core import BYTE, Contiguous, Subarray, TypeRegistry, Vector
from repro.measure import (
    DecisionCache,
    ParamsStore,
    STORE_FORMAT,
    ci_params_path,
    fit_latency_bandwidth,
    load_ci_params,
    system_fingerprint,
    time_fn,
    type_fingerprint,
)
from tests._subproc import run_with_devices

#: a handful of structurally distinct types for selection sweeps
SWEEP = (
    Vector(4096, 8, 4096, BYTE),
    Vector(16, 64, 512, BYTE),
    Vector(4, 256, 512, BYTE),
    Contiguous(1000, BYTE),
    Subarray((128, 16, 4), (48, 7, 3), (16, 2, 1), BYTE),
)


# ===========================================================================
# fingerprints
# ===========================================================================

class TestFingerprint:
    def test_same_structure_two_registries_same_key(self):
        r1, r2 = TypeRegistry(), TypeRegistry()
        for dt in SWEEP:
            a, b = r1.commit(dt), r2.commit(dt)
            assert a is not b
            assert a.fingerprint == b.fingerprint
            assert type_fingerprint(a) == a.fingerprint

    def test_recommit_same_key(self):
        r = TypeRegistry()
        a = r.commit(Vector(16, 64, 512, BYTE))
        r.clear()
        b = r.commit(Vector(16, 64, 512, BYTE))
        assert a is not b and a.fingerprint == b.fingerprint

    def test_different_structures_differ(self):
        r = TypeRegistry()
        keys = {r.commit(dt).fingerprint for dt in SWEEP}
        assert len(keys) == len(SWEEP)

    def test_equivalent_constructions_share_key(self):
        # paper Fig. 2 argument: different construction, same canonical
        # object -> same fingerprint.  (Vector strides in elements of
        # BYTE == Hvector strides in bytes; note a Subarray of the same
        # region would NOT share the key — its MPI extent spans the full
        # array, and extent is behaviorally significant under incount.)
        from repro.core import Hvector

        r = TypeRegistry()
        a = r.commit(Vector(4, 8, 16, BYTE))
        b = r.commit(Hvector(4, 8, 16, BYTE))
        assert a.datatype != b.datatype
        assert a.fingerprint == b.fingerprint

    def test_fingerprint_stable_across_processes(self):
        r = TypeRegistry()
        want = r.commit(Vector(16, 64, 512, BYTE)).fingerprint
        out = run_with_devices(
            """
            from repro.core import BYTE, TypeRegistry, Vector
            print(TypeRegistry().commit(Vector(16, 64, 512, BYTE)).fingerprint)
            """,
            ndev=1,
        )
        assert out.strip() == want

    def test_generic_type_fingerprints(self):
        # GENERIC commits (no StridedBlock) hash their canonical tree
        r = TypeRegistry()
        dt = Vector(3, 1, 2, Vector(2, 1, 3, BYTE))
        ct = r.commit(dt)
        if ct.block is None:
            assert TypeRegistry().commit(dt).fingerprint == ct.fingerprint

    def test_system_fingerprint_is_stable(self):
        assert system_fingerprint() == system_fingerprint()


# ===========================================================================
# bench harness
# ===========================================================================

class TestBench:
    def test_time_fn_warms_up_before_timing(self):
        calls = []

        def fn(x):
            calls.append(x)
            return np.zeros(1)

        sec = time_fn(fn, 1, iters=3)
        assert sec >= 0
        assert len(calls) == 4  # 1 warm-up + 3 timed

    def test_fit_latency_bandwidth(self):
        lat, bw = 2e-6, 1e9
        rows = [(x, lat + (2.0 ** x) / bw) for x in (10.0, 14.0, 18.0, 22.0)]
        got_lat, got_bw = fit_latency_bandwidth(rows)
        assert got_lat == pytest.approx(lat, rel=1e-6)
        assert got_bw == pytest.approx(bw, rel=1e-6)

    def test_fit_degenerate_returns_none(self):
        assert fit_latency_bandwidth([(10.0, 1e-6)]) == (None, None)

    def test_fit_negative_intercept_is_none_not_zero(self):
        # a noisy sweep can fit a negative latency; reporting 0.0 would
        # make t_link price extra hops as free — it must be "no fit"
        rows = [(x, -1e-6 + (2.0 ** x) / 1e9) for x in (14.0, 18.0, 22.0)]
        lat, bw = fit_latency_bandwidth(rows)
        assert lat is None
        assert bw == pytest.approx(1e9, rel=1e-6)


# ===========================================================================
# SystemParams round-trip + interpolation fallbacks
# ===========================================================================

class TestParamsRoundTrip:
    def test_full_term_tables_roundtrip(self):
        p = SystemParams(
            name="t",
            pack_table={"rows": ((1.0, 2.0, 3e-6),)},
            unpack_table={"rows": ((1.0, 2.0, 5e-6), (1.0, 3.0, 6e-6))},
            wire_table=((10.0, 2e-6), (20.0, 9e-5)),
            copy_table=((10.0, 1e-6),),
            wire_latency=1.5e-6,
            wire_bw=1e10,
        )
        q = SystemParams.from_json(p.to_json())
        assert q == p
        assert q.unpack_table["rows"][1] == (1.0, 3.0, 6e-6)

    def test_legacy_json_without_new_fields_loads(self):
        legacy = json.dumps({"name": "old", "hbm_bw": 1e9})
        p = SystemParams.from_json(legacy)
        assert p.unpack_table is None and p.wire_table is None

    def test_unknown_json_keys_ignored(self):
        p = SystemParams.from_json(json.dumps({"name": "x", "future_field": 1}))
        assert p.name == "x"

    def test_interp_nearest_neighbor_on_degenerate_grid(self):
        # single measured point: every query answers it (was: None)
        assert _interp2d(((3.0, 10.0, 7e-6),), 9.0, 20.0) == pytest.approx(7e-6)

    def test_interp_nearest_neighbor_on_sparse_hole(self):
        # 2x2 grid with one corner missing: fall back to nearest point
        table = ((3.0, 10.0, 1e-6), (3.0, 20.0, 2e-6), (9.0, 10.0, 3e-6))
        assert _interp2d(table, 8.9, 19.9) == pytest.approx(2e-6)

    def test_interp_empty_table_is_none(self):
        assert _interp2d((), 1.0, 1.0) is None


# ===========================================================================
# measured unpack + wire terms drive estimate()
# ===========================================================================

class TestMeasuredTerms:
    def _params(self):
        # flat synthetic tables so interpolated values are exact
        return SystemParams(
            name="synthetic",
            pack_table={"rows": ((1.0, 1.0, 1e-4), (30.0, 30.0, 1e-4))},
            unpack_table={"rows": ((1.0, 1.0, 7e-4), (30.0, 30.0, 7e-4))},
            wire_table=((0.0, 3e-4), (30.0, 3e-4)),
            wire_latency=2e-5,
        )

    def test_estimate_uses_measured_unpack_and_wire(self):
        reg = TypeRegistry()
        ct = reg.commit(Vector(16, 64, 512, BYTE))
        est = PerfModel(self._params()).estimate(ct, 1, "rows")
        assert est.t_pack == pytest.approx(1e-4)
        assert est.t_unpack == pytest.approx(7e-4)  # NOT 1.5 * t_pack
        assert est.t_link == pytest.approx(3e-4)

    def test_extra_hops_add_fitted_latency(self):
        m = PerfModel(self._params())
        assert m.t_link(1024, hops=3) == pytest.approx(3e-4 + 2 * 2e-5)

    def test_link_extrapolates_past_measured_grid(self):
        # beyond the largest measured size the model must charge the
        # fitted bandwidth for the excess bytes, not flat-clamp (which
        # would price 64 MiB like the 4 MiB grid ceiling)
        p = SystemParams(
            name="w",
            wire_table=((10.0, 1e-5), (20.0, 1e-5)),
            wire_latency=1e-6,
            wire_bw=1e9,
        )
        m = PerfModel(p)
        assert m.t_link(1 << 20) == pytest.approx(1e-5)  # at the edge
        want = 1e-5 + ((1 << 26) - (1 << 20)) / 1e9
        assert m.t_link(1 << 26) == pytest.approx(want)

    def test_analytic_fallback_without_tables(self):
        reg = TypeRegistry()
        ct = reg.commit(Vector(16, 64, 512, BYTE))
        est = PerfModel(TPU_V5E).estimate(ct, 1, "rows")
        assert est.t_unpack == pytest.approx(1.5 * est.t_pack)

    def test_tables_not_extrapolated_past_calibration_cap(self):
        # the xla sweep never measures past its 512-block cap, so a
        # 524288-block object must be priced analytically (~nblocks *
        # copy overhead), NOT by the nearest small-object measurement —
        # which would hand exactly the worst case to the per-block path
        params = load_ci_params()
        reg = TypeRegistry()
        big = reg.commit(Vector(524288, 8, 512, BYTE))
        model = PerfModel(params)
        t_xla = model.t_pack(big, 1, "xla")
        assert t_xla >= 524288 * params.xla_copy_overhead
        assert model.select(big).strategy != "xla"
        # ...while a within-cap object still answers from the table
        small = reg.commit(Vector(128, 8, 512, BYTE))
        assert model.measured("xla", 8, 1024) is not None
        assert model.t_pack(small, 1, "xla") == pytest.approx(
            model.measured("xla", 8, 128 * 8)
        )


# ===========================================================================
# selection cache: fingerprint-keyed, not id()-keyed
# ===========================================================================

class TestSelectionCache:
    def test_equal_structures_share_cache_entry(self):
        model = PerfModel(TPU_V5E)
        a = TypeRegistry().commit(Vector(16, 64, 512, BYTE))
        b = TypeRegistry().commit(Vector(16, 64, 512, BYTE))
        assert a is not b
        first = model.select(a)
        assert model.select(b) is first  # id(a) != id(b): content key hits
        assert model.hits == 1

    def test_two_fresh_models_same_params_agree(self):
        reg = TypeRegistry()
        for dt in SWEEP:
            ct = reg.commit(dt)
            s1 = PerfModel(TPU_V5E).select(ct).strategy
            s2 = PerfModel(TPU_V5E).select(ct).strategy
            assert s1 == s2


# ===========================================================================
# the store
# ===========================================================================

class TestStore:
    def test_save_load_roundtrip(self, tmp_path):
        store = ParamsStore(tmp_path)
        p = SystemParams(name="x", unpack_table={"dma": ((1.0, 2.0, 3e-6),)})
        store.save(p)
        assert store.load() == p

    def test_load_refuses_foreign_format(self, tmp_path):
        store = ParamsStore(tmp_path)
        p = SystemParams(name="x")
        out = store.save(p)
        d = json.loads(out.read_text())
        d["format"] = STORE_FORMAT + 1
        out.write_text(json.dumps(d))
        assert store.load() is None

    def test_load_refuses_foreign_system(self, tmp_path):
        store = ParamsStore(tmp_path)
        out = store.save(SystemParams(name="x"), system="deadbeefdeadbeef")
        assert out.name == "deadbeefdeadbeef.json"
        assert store.load() is None  # current system's slot is empty

    def test_load_or_calibrate_calibrates_once(self, tmp_path, monkeypatch):
        import repro.measure.store as store_mod

        calls = []

        def fake_calibrate(name=None, reduced=False):
            calls.append(reduced)
            return SystemParams(name="fake")

        monkeypatch.setattr(store_mod, "calibrate_params", fake_calibrate)
        store = ParamsStore(tmp_path)
        p1 = store.load_or_calibrate(reduced=True)
        p2 = store.load_or_calibrate(reduced=True)
        assert calls == [True]  # second call served from disk
        assert p1 == p2 == SystemParams(name="fake")

    def test_env_root(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_MEASURE_DIR", str(tmp_path))
        assert ParamsStore().root == tmp_path


# ===========================================================================
# the stencil-application sweep (ISSUE 5: store format 4)
# ===========================================================================

class TestStencilTable:
    def test_measure_stencil_table_rows(self):
        from repro.measure import measure_stencil_table

        rows = measure_stencil_table(
            radii_set=((1, 1, 1),), total_bytes=(1 << 10,), iters=1
        )
        assert len(rows) == 1
        log_n, log_b, sec = rows[0]
        assert log_n == pytest.approx(np.log2(26))
        assert sec > 0

    def test_stencil_table_roundtrips(self):
        p = SystemParams(
            name="t",
            stencil_table=[[np.log2(26), 12.0, 3e-5], [np.log2(26), 16.0, 4e-4]],
        )
        # frozen into tuples, JSON round-trips
        assert p.stencil_table == ((np.log2(26), 12.0, 3e-5),
                                   (np.log2(26), 16.0, 4e-4))
        assert SystemParams.from_json(p.to_json()) == p

    def test_store_format_5_and_older_envelopes_load(self, tmp_path):
        assert STORE_FORMAT == 6
        store = ParamsStore(tmp_path)
        p = SystemParams(name="x", stencil_table=((4.7, 12.0, 3e-5),))
        out = store.save(p)
        assert json.loads(out.read_text())["format"] == STORE_FORMAT
        assert store.load() == p
        # a format-4 envelope (pre-link-class) still loads
        d = json.loads(out.read_text())
        d["format"] = 4
        del d["params"]["link_tables"]
        del d["params"]["link_fits"]
        out.write_text(json.dumps(d))
        got = store.load()
        assert got is not None and got.link_tables is None
        # a format-3 envelope (pre-stencil-table) still loads
        d["format"] = 3
        del d["params"]["stencil_table"]
        out.write_text(json.dumps(d))
        got = store.load()
        assert got is not None and got.stencil_table is None
        # ...as does format 2 (pre-per-axis-wire)
        d["format"] = 2
        out.write_text(json.dumps(d))
        assert store.load() is not None

    def test_price_program_prefers_measured_stencil_rate(self):
        """With a stencil table the redundant term is the measured
        per-byte application rate x redundant bytes — not the copy
        proxy — and the cost responds to the neighbor count axis."""
        from repro.comm import PerfModel, plan_wire

        plan = plan_wire((64,), (((0, 0),),), native=False)
        interior, radii, steps = (8, 8, 8), (1, 1, 1), 2
        # application windows here span 10^3 cells = 4000 B (log2 ~12);
        # one measured point per neighbor count (nearest-neighbor interp)
        t26, t124 = 1e-3, 8e-3
        p = SystemParams(
            name="s",
            stencil_table=(
                (np.log2(26), 12.0, t26),
                (np.log2(124), 12.0, t124),
            ),
        )
        model = PerfModel(p)
        est26 = model.price_program(plan, interior, radii, 26, steps)
        est124 = model.price_program(plan, interior, (2, 2, 2), 124, 2)
        # shell-1 window = 10^3 cells, 488 redundant: rate * red_bytes
        cells, red = 10 ** 3, 10 ** 3 - 8 ** 3
        want26 = t26 * (red * 4) / (cells * 4)
        assert est26.t_redundant == pytest.approx(want26, rel=1e-6)
        # no table -> the copy/hbm proxy prices differently
        bare = PerfModel(SystemParams(name="b"))
        est_proxy = bare.price_program(plan, interior, radii, 26, steps)
        assert est_proxy.t_redundant != pytest.approx(est26.t_redundant)
        # the 124-neighbor row is consulted for the deeper op
        assert est124.t_redundant > 0


# ===========================================================================
# decisions: audit log + pinning
# ===========================================================================

class TestDecisions:
    def test_model_records_decisions(self):
        dc = DecisionCache()
        model = PerfModel(TPU_V5E, decisions=dc)
        reg = TypeRegistry()
        cts = [reg.commit(dt) for dt in SWEEP]
        picks = {ct.fingerprint: model.select(ct).strategy for ct in cts}
        assert len(dc) == len(SWEEP)
        for d in dc.log:
            assert picks[d.fingerprint] == d.strategy
        rep = dc.report()
        assert all(d.strategy in rep for d in dc.log)

    def test_roundtrip_and_pinning(self, tmp_path):
        dc = DecisionCache()
        model = PerfModel(TPU_V5E, decisions=dc)
        reg = TypeRegistry()
        ct = reg.commit(Vector(4096, 8, 4096, BYTE))
        chosen = model.select(ct).strategy
        path = dc.save(tmp_path / "decisions.json")

        reloaded = DecisionCache.load(path)
        assert len(reloaded) == 1
        model2 = PerfModel(TPU_V5E, decisions=reloaded)
        assert model2.select(reg.commit(Vector(4096, 8, 4096, BYTE))).strategy \
            == chosen
        assert reloaded.pinned_hits == 1

    def test_pinned_decision_overrides_model(self):
        # preload a decision that is NOT what the model would pick: the
        # pin must win (that is what makes CI deterministic)
        reg = TypeRegistry()
        ct = reg.commit(Contiguous(1000, BYTE))
        assert PerfModel(TPU_V5E).select(ct).strategy == "bounding"
        pinned = DecisionCache()
        pinned.record(ct.fingerprint, 1, 1, True,
                      PerfModel(TPU_V5E).estimate(ct, 1, "xla"))
        model = PerfModel(TPU_V5E, decisions=pinned)
        assert model.select(ct).strategy == "xla"

    def test_missing_file_loads_empty(self, tmp_path):
        assert len(DecisionCache.load(tmp_path / "nope.json")) == 0

    def test_rerecord_save_load_is_idempotent(self, tmp_path):
        # regression: re-recording an existing key used to append a
        # duplicate audit row, so every record -> save -> load -> record
        # cycle compounded duplicates in the persisted log
        reg = TypeRegistry()
        ct = reg.commit(Vector(4096, 8, 4096, BYTE))
        est = PerfModel(TPU_V5E).estimate(ct, 1, "rows")
        path = tmp_path / "decisions.json"

        dc = DecisionCache()
        dc.record(ct.fingerprint, 1, 1, True, est, ct=ct)
        dc.save(path)
        first = path.read_text()
        for _ in range(3):
            dc = DecisionCache.load(path)
            dc.record(ct.fingerprint, 1, 1, True, est, ct=ct)
            dc.save(path)
        assert path.read_text() == first
        assert len(DecisionCache.load(path).log) == 1

    def test_rerecord_is_last_wins_with_stable_order(self):
        reg = TypeRegistry()
        a = reg.commit(Vector(4096, 8, 4096, BYTE))
        b = reg.commit(Vector(16, 64, 512, BYTE))
        model = PerfModel(TPU_V5E)
        dc = DecisionCache()
        dc.record(a.fingerprint, 1, 1, True, model.estimate(a, 1, "rows"))
        dc.record(b.fingerprint, 1, 1, True, model.estimate(b, 1, "rows"))
        # re-record the FIRST key with a different strategy: the row is
        # replaced in place, not appended after b's
        dc.record(a.fingerprint, 1, 1, True, model.estimate(a, 1, "dma"))
        assert [d.fingerprint for d in dc.log] == [a.fingerprint, b.fingerprint]
        assert dc.log[0].strategy == "dma"
        assert len(dc) == 2

    def test_format_mismatch_raises(self, tmp_path):
        p = tmp_path / "old.json"
        p.write_text(json.dumps({"format": 999, "decisions": []}))
        with pytest.raises(ValueError, match="format"):
            DecisionCache.load(p)


# ===========================================================================
# pinned selection vs the checked-in CI params (acceptance criterion)
# ===========================================================================

_SELECT_CODE = """
import os
from repro.comm import PerfModel
from repro.core import BYTE, Contiguous, Subarray, TypeRegistry, Vector
from repro.measure import ParamsStore, load_ci_params

path = os.environ.get("REPRO_SELECT_PARAMS")
if path:
    params = ParamsStore.read_envelope(path)
    assert params is not None, f"unreadable params envelope: {path}"
else:
    params = load_ci_params()
reg = TypeRegistry()
model = PerfModel(params)
for dt in (
    Vector(4096, 8, 4096, BYTE),
    Vector(16, 64, 512, BYTE),
    Vector(4, 256, 512, BYTE),
    Contiguous(1000, BYTE),
    Subarray((128, 16, 4), (48, 7, 3), (16, 2, 1), BYTE),
):
    ct = reg.commit(dt)
    est = model.select(ct)
    print(f"{ct.fingerprint} {est.strategy}")
"""


class TestPinnedSelection:
    def test_ci_params_checked_in_and_loadable(self):
        assert ci_params_path().exists()
        params = load_ci_params()
        assert params.pack_table and params.unpack_table
        assert params.wire_table and params.copy_table

    def test_selection_reproducible_across_processes(self):
        # the acceptance criterion: two FRESH processes, same stored
        # SystemParams -> identical fingerprint-keyed selections
        out1 = run_with_devices(_SELECT_CODE, ndev=1)
        out2 = run_with_devices(_SELECT_CODE, ndev=1)
        assert out1 == out2
        assert len(out1.strip().splitlines()) == 5

    @pytest.mark.skipif(
        not os.environ.get("REPRO_CI_FRESH_PARAMS"),
        reason="set REPRO_CI_FRESH_PARAMS to a freshly calibrated envelope "
               "(the CI workflow does, after its reduced-grid calibration)",
    )
    def test_fresh_calibration_selection_reproducible(self, monkeypatch):
        # same determinism criterion, against the params measured on THIS
        # runner minutes ago — proves the property holds for any stored
        # table, not just the checked-in one
        monkeypatch.setenv(
            "REPRO_SELECT_PARAMS", os.environ["REPRO_CI_FRESH_PARAMS"]
        )
        out1 = run_with_devices(_SELECT_CODE, ndev=1)
        out2 = run_with_devices(_SELECT_CODE, ndev=1)
        assert out1 == out2
        assert len(out1.strip().splitlines()) == 5

    def test_in_process_selection_matches_subprocess(self):
        params = load_ci_params()
        reg = TypeRegistry()
        model = PerfModel(params)
        want = {}
        for line in run_with_devices(_SELECT_CODE, ndev=1).strip().splitlines():
            fp, strat = line.split()
            want[fp] = strat
        for dt in SWEEP:
            ct = reg.commit(dt)
            assert model.select(ct).strategy == want[ct.fingerprint]
