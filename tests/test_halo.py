"""Integration tests: 26-neighbor halo exchange on an 8-device mesh.

Runs in a subprocess with --xla_force_host_platform_device_count=8.
Correctness oracle: assemble the global periodic array in numpy and check
every halo cell of every rank equals the wrapped global neighbor value —
for both interposer modes (baseline per-block copies and tempi kernels),
which must agree bit-exactly.
"""

import pytest

from tests._subproc import run_with_devices

HALO_CODE = r"""
import itertools
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.comm import Interposer
from repro.halo import HaloSpec, make_halo_step

grid = (2, 2, 2)
spec = HaloSpec(grid=grid, interior=(6, 5, 4), radius=2)
r = spec.radius
nz, ny, nx = spec.interior
az, ay, ax = spec.alloc
R = spec.nranks
assert len(jax.devices()) == R

# global periodic field with unique values
gz, gy, gx = grid[0] * nz, grid[1] * ny, grid[2] * nx
gvals = np.arange(gz * gy * gx, dtype=np.float32).reshape(gz, gy, gx)

# build each rank's local block (interior filled, halos poisoned)
locals_np = np.full((R, az, ay, ax), -1.0, np.float32)
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    locals_np[rank, r:r+nz, r:r+ny, r:r+nx] = gvals[
        cz*nz:(cz+1)*nz, cy*ny:(cy+1)*ny, cx*nx:(cx+1)*nx
    ]

mesh = Mesh(np.array(jax.devices()), ("ranks",))
results = {}
for mode in ("baseline", "tempi"):
    ip = Interposer(mode=mode)
    step = make_halo_step(spec, ip, mesh)
    x0 = jnp.asarray(locals_np.reshape(R * az, ay, ax))
    out = np.asarray(step(x0))
    results[mode] = out.reshape(R, az, ay, ax)

np.testing.assert_array_equal(results["baseline"], results["tempi"])

# the whole 26-region exchange must ride the fused exact-byte wire
# schedule: one wire op per displacement class (7 on a 2x2x2 grid),
# moving exactly the sum of per-peer packed extents — no class padding.
# Forced pack strategy makes the expected byte count Σ ct.size exactly.
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import make_halo_plan
comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
plan = make_halo_plan(spec, comm, schedule_policy="exact")
step = make_halo_step(spec, comm, mesh, schedule_policy="exact")
counts = collective_payload_bytes(step, x0)
assert plan.wire.ngroups == 7
assert counts["ops"] == plan.wire.wire_ops == 7, counts
assert counts["total"] == plan.wire_bytes == plan.wire.issued_bytes, counts
assert plan.wire_bytes == sum(ct.packed_extent() for ct in plan.send_cts)
print("FUSED_OK")

# oracle: every cell (including halos) must equal the periodic global value
out = results["tempi"]
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    zz = (np.arange(az) - r + cz * nz) % gz
    yy = (np.arange(ay) - r + cy * ny) % gy
    xx = (np.arange(ax) - r + cx * nx) % gx
    want = gvals[np.ix_(zz, yy, xx)]
    np.testing.assert_array_equal(out[rank], want, err_msg=f"rank {rank}")
print("HALO_OK")
"""


STENCIL_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.comm import Communicator
from repro.compat import shard_map
from repro.halo import HaloSpec, halo_exchange, make_halo_types, stencil_iterations

grid = (2, 2, 2)
spec = HaloSpec(grid=grid, interior=(4, 4, 4), radius=2)
r = spec.radius
R = spec.nranks
az, ay, ax = spec.alloc
nz, ny, nx = spec.interior

rng = np.random.default_rng(7)
gz, gy, gx = grid[0]*nz, grid[1]*ny, grid[2]*nx
gvals = rng.normal(size=(gz, gy, gx)).astype(np.float32)

locals_np = np.zeros((R, az, ay, ax), np.float32)
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    locals_np[rank, r:r+nz, r:r+ny, r:r+nx] = gvals[
        cz*nz:(cz+1)*nz, cy*ny:(cy+1)*ny, cx*nx:(cx+1)*nx]

comm = Communicator(axis_name="ranks")
mesh = Mesh(np.array(jax.devices()), ("ranks",))
types = make_halo_types(spec, comm)

def iteration(local):
    local = halo_exchange(local, spec, comm, "ranks", types)
    return stencil_iterations(local, spec, steps=2)

step = jax.jit(shard_map(iteration, mesh=mesh, in_specs=P("ranks"),
                         out_specs=P("ranks"), check_vma=False))
out = np.asarray(step(jnp.asarray(locals_np.reshape(R*az, ay, ax)))).reshape(R, az, ay, ax)

# single-"rank" numpy oracle on the periodic global array
def stencil_np(g):
    acc = np.zeros_like(g)
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if (dz, dy, dx) == (0, 0, 0):
                    continue
                acc += np.roll(g, (-dz, -dy, -dx), axis=(0, 1, 2))
    return (1 - 0.4) * g + (0.4 / 26.0) * acc

want = stencil_np(stencil_np(gvals))
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    got = out[rank, r:r+nz, r:r+ny, r:r+nx]
    np.testing.assert_allclose(
        got, want[cz*nz:(cz+1)*nz, cy*ny:(cy+1)*ny, cx*nx:(cx+1)*nx],
        rtol=2e-6, atol=2e-6, err_msg=f"rank {rank}")
print("STENCIL_OK")
"""


@pytest.mark.slow
def test_halo_exchange_8_ranks():
    out = run_with_devices(HALO_CODE, ndev=8)
    assert "FUSED_OK" in out
    assert "HALO_OK" in out


@pytest.mark.slow
def test_stencil_matches_global_oracle():
    out = run_with_devices(STENCIL_CODE, ndev=8)
    assert "STENCIL_OK" in out
