"""Helper: run a JAX snippet in a subprocess with N emulated host devices.

jax locks the device count at first init, so multi-device CPU tests must
run in a fresh process with XLA_FLAGS set before import.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, ndev: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout
