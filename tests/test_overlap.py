"""Tests: exchange/compute overlap in the stencil iteration (ROADMAP:
steps-deep pipelining — the wire now hides behind the interior chain of
ALL fused applications, not just the first one)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import Communicator
from repro.halo import (
    HaloSpec,
    STENCIL26,
    StencilOp,
    halo_exchange,
    make_halo_types,
    max_pipeline_depth,
    overlapped_stencil_iteration,
    stencil26,
    stencil26_interior,
    stencil_interior_chain,
    stencil_steps,
)


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("ranks",))


def test_interior_update_is_halo_independent():
    """The overlap's legality: the deep-interior update must not read
    halo cells, so poisoning every halo cell cannot change it."""
    spec = HaloSpec(grid=(1, 1, 1), interior=(6, 5, 4), radius=2)
    r = spec.radius
    az, ay, ax = spec.alloc
    rng = np.random.default_rng(0)
    full = rng.normal(size=(az, ay, ax)).astype(np.float32)
    poisoned = np.full_like(full, 1e6)
    nz, ny, nx = spec.interior
    poisoned[r:r + nz, r:r + ny, r:r + nx] = full[r:r + nz, r:r + ny, r:r + nx]

    inner_poisoned = np.asarray(stencil26_interior(jnp.asarray(poisoned), spec))
    stepped_full = np.asarray(stencil26(jnp.asarray(full), spec))
    np.testing.assert_array_equal(
        inner_poisoned,
        stepped_full[r + 1:r + 1 + nz - 2, r + 1:r + 1 + ny - 2,
                     r + 1:r + 1 + nx - 2],
    )


def test_interior_chain_is_halo_independent_steps_deep():
    """Steps-deep pipelining legality: EVERY chain block must be
    poison-proof, and block k must equal the corresponding region of k
    full shrinking-region applications."""
    op = StencilOp((2, 1, 1))
    spec = HaloSpec(grid=(1, 1, 1), interior=(12, 8, 8),
                    radius=op.halo_radii(2))
    rz, ry, rx = spec.radii
    nz, ny, nx = spec.interior
    az, ay, ax = spec.alloc
    rng = np.random.default_rng(1)
    full = rng.normal(size=(az, ay, ax)).astype(np.float32)
    poisoned = np.full_like(full, 1e6)
    poisoned[rz:rz + nz, ry:ry + ny, rx:rx + nx] = \
        full[rz:rz + nz, ry:ry + ny, rx:rx + nx]

    depth = max_pipeline_depth(spec, op, 2)
    assert depth == 2
    chain = stencil_interior_chain(jnp.asarray(poisoned), spec, depth, op)

    stepped = jnp.asarray(full)
    valid = spec.radii
    for k in range(1, depth + 1):
        from repro.halo import stencil_apply

        stepped = stencil_apply(stepped, spec, valid, op)
        valid = tuple(v - r for v, r in zip(valid, op.radii))
        oz, oy, ox = (hr + k * r for hr, r in zip(spec.radii, op.radii))
        sz, sy, sx = chain[k - 1].shape
        np.testing.assert_array_equal(
            np.asarray(chain[k - 1]),
            np.asarray(stepped)[oz:oz + sz, oy:oy + sy, ox:ox + sx],
            err_msg=f"chain block {k}",
        )


def test_overlapped_iteration_matches_plain_single_rank():
    spec = HaloSpec(grid=(1, 1, 1), interior=(6, 5, 4), radius=2)
    az, ay, ax = spec.alloc
    comm = Communicator(axis_name="ranks")
    types = make_halo_types(spec, comm)
    probe = {}

    def plain(local):
        local = halo_exchange(local, spec, comm, "ranks", types)
        return stencil_steps(local, spec, steps=2)

    def overlapped(local):
        return overlapped_stencil_iteration(
            local, spec, comm, "ranks", types, steps=2, probe=probe
        )

    mesh = _mesh1()
    jp = jax.jit(shard_map(plain, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    jo = jax.jit(shard_map(overlapped, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(az, ay, ax)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(jp(x)), np.asarray(jo(x)))

    # the overlap invariant: the wire was issued but NOT waited on when
    # the interior compute was built
    assert probe["pending_during_interior"] is True
    # interior (6,5,4): the x dim (4 - 2*2 = 0) caps the chain at depth 1
    assert probe["pipeline_depth"] == 1

    # single-rank periodic grid: all 26 transfers share one delta class,
    # so the fused exact-byte schedule issues exactly one collective
    from repro.comm import collective_payload_bytes

    counts = collective_payload_bytes(jo, x)
    assert counts["ops"] == 1, counts


def test_overlapped_iteration_steps_deep_pipeline():
    """A roomier interior pipelines BOTH fused applications; result stays
    bit-identical to the plain path."""
    spec = HaloSpec(grid=(1, 1, 1), interior=(8, 7, 6), radius=2)
    az, ay, ax = spec.alloc
    comm = Communicator(axis_name="ranks")
    types = make_halo_types(spec, comm)
    probe = {}

    def plain(local):
        local = halo_exchange(local, spec, comm, "ranks", types)
        return stencil_steps(local, spec, steps=2)

    def overlapped(local):
        return overlapped_stencil_iteration(
            local, spec, comm, "ranks", types, steps=2, probe=probe
        )

    mesh = _mesh1()
    jp = jax.jit(shard_map(plain, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    jo = jax.jit(shard_map(overlapped, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(az, ay, ax)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(jp(x)), np.asarray(jo(x)))
    assert probe["pending_during_interior"] is True
    assert probe["pipeline_depth"] == 2  # both applications precomputed


OVERLAP_8RANK_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator
from repro.halo import (HaloSpec, halo_exchange, make_halo_types,
                        overlapped_stencil_iteration, stencil_steps)

spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
assert len(jax.devices()) == R

comm = Communicator(axis_name="ranks")
mesh = Mesh(np.array(jax.devices()), ("ranks",))
types = make_halo_types(spec, comm)
probe = {}

# byte-exact ladder: the 7-wire-op / ragged-bytes assertions below gate
# the exact schedule (the model-priced default may buy uniform padding)
from repro.halo import make_halo_plan
plan = make_halo_plan(spec, comm, types, schedule_policy="exact")

def plain(local):
    local = halo_exchange(local, spec, comm, "ranks", types, plan=plan)
    return stencil_steps(local, spec, steps=2)

def overlapped(local):
    return overlapped_stencil_iteration(
        local, spec, comm, "ranks", types, steps=2, probe=probe, plan=plan)

jp = jax.jit(shard_map(plain, mesh=mesh, in_specs=P("ranks"),
                       out_specs=P("ranks"), check_vma=False))
jo = jax.jit(shard_map(overlapped, mesh=mesh, in_specs=P("ranks"),
                       out_specs=P("ranks"), check_vma=False))

rng = np.random.default_rng(7)
x = jnp.asarray(rng.normal(size=(R * az, ay, ax)).astype(np.float32))
np.testing.assert_array_equal(np.asarray(jp(x)), np.asarray(jo(x)))
assert probe["pending_during_interior"] is True
assert probe["pipeline_depth"] == 1
# 2x2x2 grid: 7 delta classes -> 7 exact-payload wire ops, ragged bytes
from repro.comm import collective_payload_bytes
counts = collective_payload_bytes(jo, x)
assert counts["ops"] == plan.wire.wire_ops == 7, counts
assert counts["total"] == plan.wire_bytes, counts
print("OVERLAP_OK")
"""


@pytest.mark.slow
def test_overlapped_iteration_matches_plain_8_ranks():
    from tests._subproc import run_with_devices

    out = run_with_devices(OVERLAP_8RANK_CODE, ndev=8)
    assert "OVERLAP_OK" in out
