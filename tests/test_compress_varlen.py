"""Length-aware compressed wire transport (satellites of the varlen PR):

- compression round trips on adversarial payloads (all-zero, zero-free,
  alternating short runs, block-boundary runs) — deterministic always,
  property-based when ``hypothesis`` is installed;
- varlen truncation correctness under jit: ``stream_bytes <=
  wire_bytes`` invariant, traced bytes == ``issued_bytes``, bit-exact
  against the capacity (grouped) transport;
- honest accounting: compress counters, ratio telemetry ring, decision
  signatures carrying ``stream_bytes=``/``ratio=``;
- the compress-throughput sweep + measure-store format 6 round trip;
- ratio drift detection and ``demote_stale_compress``;
- the gradient wire (``GradWire`` / ``make_grad_step``) end to end.
"""

import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import (
    Communicator,
    FixedPolicy,
    INT8_WIRE,
    RLE_WIRE,
    RleWire,
)
from repro.comm.compress import RLE_HEADER_BYTES, RLE_RUN_BYTES
from repro.comm.perfmodel import SystemParams, TPU_V5E
from repro.comm.wireplan import collective_payload_bytes, reschedule
from repro.core import BYTE, FLOAT, Subarray, TypeRegistry, Vector
from repro.fleet import (
    DriftDetector,
    ExchangeTelemetry,
    demote_stale_compress,
    remeasure_term,
)
from repro.measure.decisions import Decision, DecisionCache


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("x",))


def _nruns(member: np.ndarray) -> int:
    return int(np.count_nonzero(member[1:] != member[:-1])) + 1


def _byte_ct(n: int):
    """A contiguous n-byte committed type (pack == identity)."""
    return TypeRegistry().commit(Vector(1, n, n, BYTE))


# the adversarial payload zoo: name -> member bytes.  Every entry is a
# shape the run-length layout can get wrong — degenerate run counts,
# runs straddling the 5-byte record and 256-element quantization
# boundaries, and streams that exactly fill / just overflow capacity.
def _adversarial_payloads():
    out = {}
    n = 1024
    out["all_zero"] = np.zeros(n, np.uint8)
    # no zero byte anywhere AND no two equal neighbours: run count == n,
    # which cannot fit n//5 run slots -> stored mode
    out["zero_free"] = (np.arange(n, dtype=np.int64) % 7 + 1).astype(np.uint8)
    # alternating short runs of length 2: n//2 runs, still > capacity
    out["alt_short_runs"] = np.repeat(
        np.tile(np.array([1, 2], np.uint8), n // 4), 2
    )
    # runs whose boundaries land exactly on the 5-byte record stride and
    # the 256-byte quantization block edge
    block = np.zeros(n, np.uint8)
    block[:RLE_RUN_BYTES] = 9          # one run exactly one record wide
    block[256:512] = 3                 # run spanning a full 256-block
    block[511:513] = 7                 # run straddling a block boundary
    out["block_boundary_runs"] = block
    # exactly at the run-capacity cliff: R = n // 5 runs fits the fixed
    # record layout with zero slack (one more run would ship stored)
    R = n // RLE_RUN_BYTES
    cap = np.zeros(n, np.uint8)
    cap[: R - 1] = np.arange(R - 1) % 2 + 1  # R-1 length-1 runs + zero tail
    assert _nruns(cap) == R
    out["at_run_capacity"] = cap
    rng = np.random.RandomState(0)
    out["random"] = rng.randint(0, 256, n).astype(np.uint8)
    out["single_byte"] = np.array([42], np.uint8)
    out["empty_tail"] = np.concatenate(
        [rng.randint(0, 4, 64).astype(np.uint8), np.zeros(960, np.uint8)]
    )
    return out


# ===========================================================================
# round trips (deterministic)
# ===========================================================================

class TestRleRoundTrip:
    @pytest.mark.parametrize("name", sorted(_adversarial_payloads()))
    def test_capacity_wire_round_trips_bit_exact(self, name):
        member = _adversarial_payloads()[name]
        n = member.size
        wire = np.asarray(RLE_WIRE.encode_wire(jnp.asarray(member)))
        assert wire.shape[0] == RLE_HEADER_BYTES + n  # capacity layout
        out = np.asarray(RLE_WIRE.decode_wire(jnp.asarray(wire), n))
        np.testing.assert_array_equal(out, member)

    @pytest.mark.parametrize("name", sorted(_adversarial_payloads()))
    def test_stream_prefix_decodes_when_rle_mode(self, name):
        """The live stream is a literal prefix of the capacity wire:
        decoding ``wire[:probe_stream_bytes]`` must reproduce the member
        bytes whenever the payload fits rle mode; a stored-mode payload
        must report stream == capacity (never truncates)."""
        member = _adversarial_payloads()[name]
        n = member.size
        ct = _byte_ct(n)
        cap = RLE_WIRE.wire_bytes(ct)
        stream = RLE_WIRE.probe_stream_bytes(ct, 1, jnp.asarray(member))
        assert stream <= cap  # the invariant the transport relies on
        runs = _nruns(member)
        if runs > n // RLE_RUN_BYTES:
            assert stream == cap  # stored mode: stream IS the capacity
            return
        assert stream == RLE_HEADER_BYTES + RLE_RUN_BYTES * runs
        wire = np.asarray(RLE_WIRE.encode_wire(jnp.asarray(member)))
        out = np.asarray(
            RLE_WIRE.decode_wire(jnp.asarray(wire[:stream]), n)
        )
        np.testing.assert_array_equal(out, member)

    def test_mode_matches_run_capacity(self):
        # a compressible payload ships rle (mode 1), an incompressible
        # one ships stored (mode 0) — read back from the wire header
        for name, member in _adversarial_payloads().items():
            if member.size < RLE_RUN_BYTES:
                continue
            wire = np.asarray(RLE_WIRE.encode_wire(jnp.asarray(member)))
            mode = int(wire[:4].view(np.uint32)[0])
            fits = _nruns(member) <= member.size // RLE_RUN_BYTES
            assert mode == (1 if fits else 0), name

    def test_decode_rejects_ragged_stream_lengths(self):
        member = np.zeros(100, np.uint8)
        wire = np.asarray(RLE_WIRE.encode_wire(jnp.asarray(member)))
        # neither capacity (108) nor header + whole 5-byte records
        with pytest.raises(ValueError, match="rle wire"):
            RLE_WIRE.decode_wire(jnp.asarray(wire[:11]), 100)
        with pytest.raises(ValueError, match="rle wire"):
            RLE_WIRE.decode_wire(jnp.asarray(wire[:4]), 100)

    def test_round_trip_under_jit(self):
        member = _adversarial_payloads()["block_boundary_runs"]
        n = member.size
        enc = jax.jit(RLE_WIRE.encode_wire)
        dec = jax.jit(lambda w: RLE_WIRE.decode_wire(w, n))
        out = np.asarray(dec(enc(jnp.asarray(member))))
        np.testing.assert_array_equal(out, member)


class TestInt8RoundTrip:
    @pytest.mark.parametrize("n", [64, 256, 1000])
    def test_quantized_round_trip_is_close(self, n):
        rng = np.random.RandomState(1)
        f = rng.randn(n).astype(np.float32)
        member = f.view(np.uint8)
        wire = INT8_WIRE.encode_wire(jnp.asarray(member))
        out = np.asarray(
            INT8_WIRE.decode_wire(wire, member.size)
        ).view(np.float32)
        assert np.max(np.abs(out - f)) <= np.max(np.abs(f)) / 127 + 1e-7

    def test_all_zero_floats_survive_exactly(self):
        member = np.zeros(256, np.uint8)
        wire = INT8_WIRE.encode_wire(jnp.asarray(member))
        out = np.asarray(INT8_WIRE.decode_wire(wire, 256))
        np.testing.assert_array_equal(out, member)

    def test_int8_never_truncates_and_stays_opt_in(self):
        # lossy wire: the base-class probe reports capacity (no stream
        # to truncate at) and the strategy is never auto-selected
        n = 256
        ct = _byte_ct(n)
        probe = INT8_WIRE.probe_stream_bytes(
            ct, 1, jnp.zeros((n,), jnp.uint8)
        )
        assert probe == INT8_WIRE.wire_bytes(ct)
        assert not getattr(INT8_WIRE, "supports_varlen", False)
        assert not INT8_WIRE.selectable


# ===========================================================================
# round trips (property-based; skipped when hypothesis is absent)
# ===========================================================================

class TestRleProperties:
    def test_arbitrary_payloads_round_trip(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=200, deadline=None)
        @given(
            st.lists(st.integers(0, 255), min_size=1, max_size=512),
        )
        def check(data):
            member = np.array(data, np.uint8)
            n = member.size
            wire = np.asarray(RLE_WIRE.encode_wire(jnp.asarray(member)))
            assert wire.shape[0] == RLE_HEADER_BYTES + n
            out = np.asarray(RLE_WIRE.decode_wire(jnp.asarray(wire), n))
            np.testing.assert_array_equal(out, member)
            ct = _byte_ct(n)
            stream = RLE_WIRE.probe_stream_bytes(ct, 1, jnp.asarray(member))
            assert stream <= RLE_WIRE.wire_bytes(ct)
            if stream < RLE_WIRE.wire_bytes(ct):
                trunc = np.asarray(
                    RLE_WIRE.decode_wire(jnp.asarray(wire[:stream]), n)
                )
                np.testing.assert_array_equal(trunc, member)

        check()

    def test_run_structured_payloads_round_trip(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=100, deadline=None)
        @given(
            st.lists(
                st.tuples(st.integers(0, 255), st.integers(1, 64)),
                min_size=1, max_size=32,
            ),
        )
        def check(runs):
            member = np.concatenate(
                [np.full(c, v, np.uint8) for v, c in runs]
            )
            wire = np.asarray(RLE_WIRE.encode_wire(jnp.asarray(member)))
            out = np.asarray(
                RLE_WIRE.decode_wire(jnp.asarray(wire), member.size)
            )
            np.testing.assert_array_equal(out, member)

        check()


# ===========================================================================
# the varlen transport under jit
# ===========================================================================

def _halo_setup(telemetry=None):
    """The canonical probed halo exchange: one rank, zero-heavy
    16x16-core Subarray with a 4-wide halo — the probe compresses, so
    selection picks rlewire and the model prices the varlen schedule."""
    comm = Communicator(axis_name="x", telemetry=telemetry)
    ct = comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
    src = np.zeros((32, 32), np.float32)
    src[10:12, 6:8] = 3.0  # a short nonzero patch inside the halo shell
    perms = [[(0, 0)]]
    strats, plan = comm.plan_neighbor(
        [ct], perms, probe=jnp.asarray(src)
    )
    return comm, ct, src, perms, strats, plan


def _run_exchange(comm, ct, src, perms, strats, plan):
    def body(buf):
        return comm.neighbor_alltoallv(
            buf, [ct], [ct], perms, plan=plan, strategies=strats
        )

    fn = jax.jit(shard_map(
        body, mesh=_mesh1(), in_specs=P(), out_specs=P(), check_vma=False
    ))
    return fn, np.asarray(fn(jnp.asarray(src)))


class TestVarlenTransport:
    def test_probed_plan_selects_varlen_rle(self):
        comm, ct, src, perms, strats, plan = _halo_setup()
        assert strats[0].name == RleWire.name
        assert plan.schedule == "varlen"
        assert plan.stream_bytes  # annotated
        # the invariant: every class's stream fits its capacity slot
        for sb, g in zip(plan.stream_bytes, plan.groups):
            assert 0 < sb <= g.nbytes
        assert plan.effective_wire_bytes < plan.wire_bytes
        assert plan.issued_bytes == plan.effective_wire_bytes
        assert 0.0 < plan.stream_ratio < 1.0

    def test_traced_bytes_equal_issued_bytes(self):
        comm, ct, src, perms, strats, plan = _halo_setup()
        fn, _ = _run_exchange(comm, ct, src, perms, strats, plan)
        counts = collective_payload_bytes(fn, jnp.asarray(src))
        assert counts["total"] == plan.issued_bytes
        assert counts["total"] < plan.wire_bytes  # strictly fewer bytes

    def test_varlen_is_bit_exact_against_capacity_transport(self):
        comm, ct, src, perms, strats, plan = _halo_setup()
        _, out_varlen = _run_exchange(comm, ct, src, perms, strats, plan)
        cap_plan = reschedule(plan, "grouped")
        assert cap_plan.issued_bytes == cap_plan.wire_bytes
        _, out_cap = _run_exchange(comm, ct, src, perms, strats, cap_plan)
        np.testing.assert_array_equal(out_varlen, out_cap)
        # the self-permute halo exchange reproduces the halo shell
        np.testing.assert_array_equal(
            out_varlen[10:12, 6:8], src[10:12, 6:8]
        )

    def test_dense_probe_honestly_declines_varlen(self):
        # an incompressible probe must not buy the compressed wire
        comm = Communicator(axis_name="x")
        ct = comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
        rng = np.random.RandomState(2)
        src = rng.randn(32, 32).astype(np.float32)
        strats, plan = comm.plan_neighbor(
            [ct], [[(0, 0)]], probe=jnp.asarray(src)
        )
        assert plan.schedule != "varlen"
        assert strats[0].name != RleWire.name

    def test_compress_counters_and_stats(self):
        comm, ct, src, perms, strats, plan = _halo_setup()
        fn, _ = _run_exchange(comm, ct, src, perms, strats, plan)
        jax.block_until_ready(fn(jnp.asarray(src)))
        s = comm.stats()
        assert s["compress_exchanges"] >= 1
        assert s["compress_capacity_bytes"] >= plan.wire_bytes
        assert s["compress_stream_bytes"] >= plan.effective_wire_bytes
        assert s["compress_stream_bytes"] < s["compress_capacity_bytes"]
        assert 0.0 < s["compress_ratio"] < 1.0

    def test_ratio_gauge_published(self):
        from repro.obs.metrics import MetricsRegistry, publish_comm_stats

        comm, ct, src, perms, strats, plan = _halo_setup()
        fn, _ = _run_exchange(comm, ct, src, perms, strats, plan)
        reg = MetricsRegistry()
        publish_comm_stats(comm.stats(), registry=reg)
        assert 0.0 < reg.gauge("comm.compress.ratio") < 1.0
        assert reg.counter("comm.compress.stream_bytes") == comm.stats()[
            "compress_stream_bytes"
        ]

    def test_ratio_telemetry_ring_registered_and_observed(self):
        tel = ExchangeTelemetry()
        comm, ct, src, perms, strats, plan = _halo_setup(telemetry=tel)
        ring = tel.get(f"{plan.fingerprint}/ratio")
        assert ring is not None and ring.strategy == "compress/ratio"
        assert ring.predicted == pytest.approx(plan.stream_ratio)
        fn, _ = _run_exchange(comm, ct, src, perms, strats, plan)
        assert ring.count >= 1
        assert ring.mean == pytest.approx(plan.stream_ratio)

    def test_decision_signature_carries_stream_and_ratio(self):
        dc = DecisionCache()
        comm = Communicator(axis_name="x", decisions=dc)
        ct = comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
        src = np.zeros((32, 32), np.float32)
        src[10:12, 6:8] = 3.0
        _, plan = comm.plan_neighbor([ct], [[(0, 0)]],
                                     probe=jnp.asarray(src))
        rows = [d for d in dc.log if d.strategy == "wire/varlen"]
        assert len(rows) == 1
        assert f"stream_bytes={plan.effective_wire_bytes}" in rows[0].signature
        assert "ratio=" in rows[0].signature
        sel = [d for d in dc.log if d.strategy == RleWire.name]
        assert sel and " stream_bytes=" in f" {sel[0].signature}"

    def test_with_stream_bytes_clamps_and_validates(self):
        comm, ct, src, perms, strats, plan = _halo_setup()
        base = reschedule(plan, "grouped")
        with pytest.raises(ValueError, match="one length per delta class"):
            base.with_stream_bytes((1,) * (base.ngroups + 1))
        huge = base.with_stream_bytes((10 ** 9,) * base.ngroups)
        assert huge.stream_bytes == tuple(g.nbytes for g in base.groups)
        assert huge.effective_wire_bytes == base.wire_bytes

    def test_reschedule_to_varlen_requires_stream_annotation(self):
        comm = Communicator(axis_name="x")
        ct = comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
        _, plan = comm.plan_neighbor([ct], [[(0, 0)]])  # no probe
        assert not plan.stream_bytes
        with pytest.raises(ValueError, match="stream-annotated"):
            reschedule(plan, "varlen")

    def test_stream_annotation_keys_the_fingerprint(self):
        comm, ct, src, perms, strats, plan = _halo_setup()
        plain = dataclasses.replace(
            reschedule(plan, "grouped"), stream_bytes=()
        )
        assert plan.fingerprint != plain.fingerprint


# ===========================================================================
# the compress-throughput sweep + store format
# ===========================================================================

class TestCompressTable:
    def test_sweep_rows_are_well_formed(self):
        from repro.measure.bench import measure_compress_table

        table = measure_compress_table(
            total_bytes=(1 << 10, 1 << 12), iters=1
        )
        assert set(table) == {"rlewire", "int8wire"}
        for name, rows in table.items():
            assert len(rows) == 2
            for log2n, csec, dsec, ratio in rows:
                assert csec > 0 and dsec > 0
                assert 0.0 < ratio <= 1.0 + 1e-9, name
            # the zero-heavy sweep payload compresses hard under rle
            if name == "rlewire":
                assert all(r[3] < 0.5 for r in rows)

    def test_measured_compress_interpolates_after_json_round_trip(self):
        from repro.measure.bench import measure_compress_table

        table = measure_compress_table(
            total_bytes=(1 << 10, 1 << 12), iters=1
        )
        params = dataclasses.replace(
            TPU_V5E, name="compress-test",
            compress_table={k: tuple(v) for k, v in table.items()},
        )
        back = SystemParams.from_json(params.to_json())
        from repro.comm.perfmodel import PerfModel

        model = PerfModel(back)
        m = model.measured_compress("rlewire", 1 << 11)
        assert m is not None and m[0] > 0 and m[1] > 0
        assert model.measured_compress("nosuch", 1 << 11) is None

    def test_store_round_trip_format_6(self, tmp_path):
        from repro.measure.store import (
            COMPATIBLE_FORMATS,
            STORE_FORMAT,
            ParamsStore,
        )

        assert STORE_FORMAT == 6
        params = dataclasses.replace(
            TPU_V5E, name="fmt6",
            compress_table={"rlewire": ((10.0, 1e-5, 1e-5, 0.05),)},
        )
        store = ParamsStore(tmp_path)
        store.save(params, system="s")
        loaded = store.load("s")
        assert loaded.compress_table["rlewire"][0][3] == 0.05
        # a format-5 envelope (predates compress_table) still loads
        assert 5 in COMPATIBLE_FORMATS
        path = store.path_for("s")
        d = json.loads(path.read_text())
        d["format"] = 5
        d["params"].pop("compress_table", None)
        path.write_text(json.dumps(d))
        old = store.load("s")
        assert old is not None and not old.compress_table


# ===========================================================================
# ratio drift + demotion
# ===========================================================================

def _varlen_decision(fp="wp-varlen", ratio=0.05):
    return Decision(
        fp, 1, 1, True, "wire/varlen", 0.0, 1e-6, 0.0,
        f"exchange schedule=varlen stream_bytes=53 ratio={ratio:g} "
        f"priced[grouped=2e-06 varlen=1e-06]", 1032,
    )


class TestCompressDrift:
    def test_decayed_ratio_ring_flags_compress_drift(self):
        dc = DecisionCache([
            _varlen_decision(),
            Decision("ct-halo", 1, 1, True, "rlewire", 1e-6, 1e-6, 1e-6,
                     "subarray stream_bytes=53 ratio=0.05", 1032),
        ])
        tel = ExchangeTelemetry()
        tel.register("wp-varlen/ratio", 0.05, "compress/ratio")
        for _ in range(8):
            tel.observe("wp-varlen/ratio", 0.40)  # payload stopped compressing
        report = DriftDetector(min_samples=4).audit(
            dc, TPU_V5E, telemetry=tel, system="t"
        )
        flagged = [f for f in report.drifted if f.term == "compress"]
        assert len(flagged) == 1
        f = flagged[0]
        assert f.strategy == "wire/varlen" and f.source == "telemetry"
        assert f.ratio == pytest.approx(0.40 / 0.05)
        # demotion drops the schedule pin AND the probed selection row
        labels = demote_stale_compress(dc, report)
        assert set(labels) == {"wire/varlen@wp-varlen", "rlewire@ct-halo"}
        assert len(dc) == 0

    def test_healthy_ratio_ring_stays_pinned(self):
        dc = DecisionCache([_varlen_decision()])
        tel = ExchangeTelemetry()
        tel.register("wp-varlen/ratio", 0.05, "compress/ratio")
        for _ in range(8):
            tel.observe("wp-varlen/ratio", 0.052)
        report = DriftDetector(min_samples=4).audit(
            dc, TPU_V5E, telemetry=tel, system="t"
        )
        assert not [f for f in report.drifted if f.term == "compress"]
        assert demote_stale_compress(dc, report) == []
        assert len(dc) == 1

    def test_demote_leaves_unrelated_rows(self):
        dc = DecisionCache([
            _varlen_decision(),
            Decision("other", 1, 1, True, "rows", 1e-6, 1e-6, 1e-6,
                     "vec", 64),
            Decision("wp2", 2, 3, True, "wire/grouped", 0.0, 1e-6, 0.0,
                     "exchange", 4096),
        ])
        tel = ExchangeTelemetry()
        tel.register("wp-varlen/ratio", 0.05, "compress/ratio")
        for _ in range(8):
            tel.observe("wp-varlen/ratio", 0.40)
        report = DriftDetector(min_samples=4).audit(
            dc, TPU_V5E, telemetry=tel, system="t"
        )
        assert demote_stale_compress(dc, report) == ["wire/varlen@wp-varlen"]
        assert {d.strategy for d in dc.log} == {"rows", "wire/grouped"}

    def test_remeasure_compress_term_refreshes_the_table(self):
        params = dataclasses.replace(TPU_V5E, name="rm", compress_table={})
        fresh = remeasure_term(params, "compress", iters=1)
        assert set(fresh.compress_table) == {"rlewire", "int8wire"}
        assert fresh.compress_table["rlewire"]
        # the other tables are untouched (targeted re-measurement)
        assert fresh.wire_table == params.wire_table


# ===========================================================================
# the gradient wire
# ===========================================================================

def _grad_tree():
    rng = np.random.RandomState(3)
    emb = np.zeros((64, 16), np.float32)
    emb[5] = rng.randn(16)  # sparsely-updated embedding: zero-heavy
    w = np.zeros((16, 16), np.float32)
    w[3, :4] = rng.randn(4) * 0.1
    return {
        "emb": jnp.asarray(emb),
        "w": jnp.asarray(w),
        "b": jnp.asarray(np.zeros((16,), np.float32)),
    }


class TestGradWire:
    def test_unknown_mode_raises(self):
        from repro.train import GradWire

        with pytest.raises(ValueError, match="unknown grad-wire mode"):
            GradWire(Communicator(axis_name="x"), mode="zstd")

    def test_off_mode_is_a_passthrough(self):
        from repro.train import GradWire

        wire = GradWire(Communicator(axis_name="x"), mode="off")
        grads = _grad_tree()
        assert wire.exchange(grads) is grads
        assert not wire.planned

    @pytest.mark.parametrize("mode", ["auto", "rle"])
    def test_lossless_modes_round_trip_bit_exact(self, mode):
        from repro.train import GradWire

        dc = DecisionCache()
        comm = Communicator(axis_name="x", decisions=dc)
        wire = GradWire(comm, mode=mode)
        grads = _grad_tree()
        out = wire.exchange(grads)
        assert wire.planned
        for k in grads:
            np.testing.assert_array_equal(
                np.asarray(out[k]), np.asarray(grads[k]), err_msg=k
            )
        desc = wire.describe()
        assert f"mode={mode}" in desc and "schedule=" in desc
        assert [d for d in dc.log if d.strategy.startswith("wire/")]

    def test_forced_rle_rides_the_varlen_wire(self):
        from repro.train import GradWire

        comm = Communicator(axis_name="x")
        wire = GradWire(comm, mode="rle")
        wire.plan_for(_grad_tree())
        assert wire._strats[0].name == RleWire.name
        p = wire._plan_fwd
        # the zero-heavy gradient probe annotates a real stream
        assert p.stream_bytes and p.effective_wire_bytes < p.wire_bytes
        assert p.schedule == "varlen"

    def test_int8_mode_is_lossy_but_close_and_opt_in(self):
        from repro.train import GradWire

        comm = Communicator(axis_name="x")
        wire = GradWire(comm, mode="int8")
        grads = _grad_tree()
        out = wire.exchange(grads)
        assert wire._strats[0].name == "int8wire"
        assert not wire._plan_fwd.stream_bytes  # lossy: never probed
        for k in grads:
            g = np.asarray(grads[k])
            o = np.asarray(out[k])
            tol = 2 * (np.max(np.abs(g)) / 127 + 1e-7)  # two quantize hops
            assert np.max(np.abs(o - g)) <= tol, k

    def test_exchange_traces_exactly_the_planned_bytes(self):
        from repro.train import GradWire

        comm = Communicator(axis_name="x")
        wire = GradWire(comm, mode="rle")
        grads = _grad_tree()
        wire.plan_for(grads)
        wire._exchange_fn = wire._build(grads)
        # the jitted exchange moves fwd + back issued bytes, nothing more
        fn = wire._exchange_fn

        def flatcall(*leaves):
            tree = jax.tree.unflatten(jax.tree.structure(grads), leaves)
            return fn(tree)

        counts = collective_payload_bytes(
            flatcall, *jax.tree.leaves(grads)
        )
        expect = wire._plan_fwd.issued_bytes + wire._plan_back.issued_bytes
        assert counts["total"] == expect


class TestGradStepFactories:
    def _tiny(self):
        from repro.configs.base import ModelConfig, ShapeConfig
        from repro.data.pipeline import synthetic_batch
        from repro.models.model import build_model
        from repro.train.optimizer import AdamWConfig, init_opt_state

        cfg = ModelConfig(
            name="tiny", family="dense", num_layers=1, d_model=16,
            num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
        )
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(total_steps=10)
        opt = init_opt_state(params, opt_cfg)
        batch = synthetic_batch(cfg, ShapeConfig("train", 8, 2, "train"), 0)
        return model, opt_cfg, params, opt, batch

    def test_split_factories_compose_to_the_fused_step(self):
        from repro.train import make_grad_step
        from repro.train.train_step import make_train_step

        model, opt_cfg, params, opt, batch = self._tiny()
        fused = make_train_step(model, opt_cfg)
        p1, o1, m1 = jax.jit(fused)(params, opt, batch)
        grad_fn, update_fn = make_grad_step(model, opt_cfg)
        loss, metrics, grads = jax.jit(grad_fn)(params, batch)
        p2, o2, m2 = jax.jit(update_fn)(params, opt, grads, loss, metrics)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6
            ),
            (p1, m1["loss"]), (p2, m2["loss"]),
        )

    def test_wire_between_the_halves_preserves_training(self):
        from repro.train import GradWire, make_grad_step
        from repro.train.train_step import make_train_step

        model, opt_cfg, params, opt, batch = self._tiny()
        fused = make_train_step(model, opt_cfg)
        p1, _, m1 = jax.jit(fused)(params, opt, batch)
        grad_fn, update_fn = make_grad_step(model, opt_cfg)
        wire = GradWire(Communicator(axis_name="x"), mode="rle")
        loss, metrics, grads = jax.jit(grad_fn)(params, batch)
        grads = wire.exchange(grads)  # lossless: must not perturb the step
        p2, _, m2 = jax.jit(update_fn)(params, opt, grads, loss, metrics)
        np.testing.assert_allclose(
            float(m1["loss"]), float(m2["loss"]), rtol=1e-6
        )
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6
            ),
            p1, p2,
        )
