"""Tests: the deep-halo HaloProgram layer (ISSUE 4).

Covers the per-dimension stencil kernels (shrinking valid region, no
symmetric-radius guard), HaloProgram bit-exactness against the naive
per-step reference for s in {1,2,3} x per-dim radii (2,1,1), the
``price_program`` oracle on the CI-pinned params, ``--halo-steps auto``
pinning through the DecisionCache, the model-priced wire-schedule
choice, the per-block Int8Wire format, and the (gated) native ragged
collective integration.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import has_ragged_all_to_all, shard_map
from repro.comm import (
    Communicator,
    FixedPolicy,
    INT8_WIRE,
    Int8Wire,
    PerfModel,
    SystemParams,
    collective_payload_bytes,
    reschedule,
)
from repro.core import BYTE, FLOAT, Subarray
from repro.halo import (
    HaloSpec,
    STENCIL26,
    StencilOp,
    build_halo_program,
    cycle_radii,
    get_default_halo_steps,
    halo_exchange,
    op_sequence,
    program_fingerprint,
    set_default_halo_steps,
    stencil_apply,
    stencil_cycle,
    stencil_steps,
)
from repro.measure import DecisionCache, load_ci_params
from tests._subproc import run_with_devices


def _mesh1(axis="ranks"):
    return Mesh(np.array(jax.devices()[:1]), (axis,))


def _stencil_np(a, op):
    """Periodic numpy oracle for one StencilOp application."""
    acc = np.zeros_like(a)
    for d in op.offsets:
        acc += np.roll(a, tuple(-x for x in d), axis=(0, 1, 2))
    w = np.float32(op.weight)
    return (np.float32(1) - w) * a + (w / np.float32(op.nneighbors)) * acc


# ===========================================================================
# per-dimension stencil kernels
# ===========================================================================

class TestStencilOp:
    def test_offsets_and_radii(self):
        assert STENCIL26.nneighbors == 26
        assert len(STENCIL26.offsets) == 26
        op = StencilOp((2, 1, 1))
        assert op.nneighbors == 5 * 3 * 3 - 1 == len(op.offsets)
        assert op.halo_radii(3) == (6, 3, 3)
        with pytest.raises(ValueError, match="positive"):
            StencilOp((0, 1, 1))

    def test_apply_validates_valid_depth(self):
        spec = HaloSpec(grid=(1, 1, 1), interior=(4, 4, 4), radius=1)
        x = jnp.zeros(spec.alloc, jnp.float32)
        with pytest.raises(ValueError, match="shallower"):
            stencil_apply(x, spec, valid=(0, 0, 0))
        with pytest.raises(ValueError, match="exhaust"):
            stencil_steps(x, spec, steps=2)

    def test_per_dim_stencil_matches_periodic_oracle(self):
        """Asymmetric radii (2,1,1), two fused steps on one exchange, on
        the single-rank periodic domain — the scalar_radius guard is
        gone and the per-dim path must match the roll oracle."""
        op = StencilOp((2, 1, 1))
        spec = HaloSpec(grid=(1, 1, 1), interior=(8, 7, 6),
                        radius=op.halo_radii(2))
        rz, ry, rx = spec.radii
        nz, ny, nx = spec.interior
        comm = Communicator(axis_name="ranks")
        rng = np.random.default_rng(0)
        g = rng.normal(size=spec.interior).astype(np.float32)
        local = np.zeros(spec.alloc, np.float32)
        local[rz:rz + nz, ry:ry + ny, rx:rx + nx] = g

        def it(x):
            x = halo_exchange(x, spec, comm, "ranks")
            return stencil_steps(x, spec, 2, op)

        fn = jax.jit(shard_map(it, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        out = np.asarray(fn(jnp.asarray(local)))
        want = _stencil_np(_stencil_np(g, op), op)
        np.testing.assert_allclose(
            out[rz:rz + nz, ry:ry + ny, rx:rx + nx], want,
            rtol=2e-6, atol=2e-6,
        )


# ===========================================================================
# HaloProgram: build, validate, price, pin
# ===========================================================================

class TestBuildProgram:
    def test_fixed_steps_and_geometry(self):
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
        prog = build_halo_program((2, 2, 2), (6, 5, 4), comm, steps=2)
        assert prog.steps == 2
        assert prog.spec.radii == (2, 2, 2)
        assert prog.exchanges_per_step == 0.5
        assert prog.plan.wire_bytes == sum(
            ct.packed_extent() for ct in prog.plan.send_cts
        )

    def test_infeasible_depth_raises(self):
        comm = Communicator(axis_name="ranks")
        with pytest.raises(ValueError, match="cannot host"):
            build_halo_program((2, 2, 2), (4, 4, 4), comm, steps=5)

    def test_default_steps_follow_process_setting(self):
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
        before = get_default_halo_steps()
        try:
            set_default_halo_steps(2)
            prog = build_halo_program((2, 2, 2), (6, 5, 4), comm)
            assert prog.steps == 2
        finally:
            set_default_halo_steps(before)

    def test_fingerprint_content_keyed(self):
        a = program_fingerprint((2, 2, 2), (6, 5, 4), STENCIL26, FLOAT)
        b = program_fingerprint((2, 2, 2), (6, 5, 4), STENCIL26, FLOAT)
        c = program_fingerprint((2, 2, 2), (6, 5, 4), StencilOp((2, 1, 1)),
                                FLOAT)
        assert a == b != c

    def test_price_program_oracle_on_ci_params(self):
        """The auto chooser must never select a depth whose predicted
        per-step cost exceeds step-per-exchange, on the CI-pinned
        measured tables (regression oracle for the model)."""
        comm = Communicator(axis_name="ranks", params=load_ci_params(),
                            policy=FixedPolicy("rows"))
        prog = build_halo_program((2, 2, 2), (8, 8, 8), comm, steps="auto")
        assert prog.candidates, "auto must price the candidate depths"
        by_steps = {e.steps: e for e in prog.candidates}
        assert 1 in by_steps
        assert prog.estimate.per_step <= by_steps[1].per_step
        # deeper halos must price strictly more wire bytes per exchange
        wire = [by_steps[s].wire_bytes for s in sorted(by_steps)]
        assert wire == sorted(wire) and wire[0] < wire[-1]

    def test_auto_choice_pinned_across_processes(self):
        dc = DecisionCache()
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                            decisions=dc)
        prog = build_halo_program((2, 2, 2), (6, 5, 4), comm, steps="auto")
        assert not prog.pinned
        rows = [d for d in dc.log if d.strategy.startswith("program/s=")]
        assert len(rows) == 1
        assert rows[0].strategy == f"program/s={prog.steps}"
        assert rows[0].wire_bytes == prog.estimate.wire_bytes
        assert f"s={prog.steps}:" in rows[0].signature

        # "another process": the decision file round-trips and pins
        dc2 = DecisionCache.from_json(dc.to_json())
        comm2 = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                             decisions=dc2)
        prog2 = build_halo_program((2, 2, 2), (6, 5, 4), comm2, steps="auto")
        assert prog2.pinned
        assert prog2.steps == prog.steps
        assert dc2.pinned_hits >= 1
        # pinned path prices nothing: no second program row recorded
        assert len([d for d in dc2.log
                    if d.strategy.startswith("program/s=")]) == 1

    def test_pin_beyond_max_steps_is_repriced(self):
        """A pin recorded under a looser cap must not smuggle a deeper
        halo past this caller's max_steps."""
        dc = DecisionCache()
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                            decisions=dc)
        prog = build_halo_program((2, 2, 2), (6, 5, 4), comm, steps="auto")
        assert prog.steps > 1  # analytic latency dominates: fuses deeper
        cap = prog.steps - 1
        dc2 = DecisionCache.from_json(dc.to_json())
        comm2 = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                             decisions=dc2)
        prog2 = build_halo_program((2, 2, 2), (6, 5, 4), comm2,
                                   steps="auto", max_steps=cap)
        assert not prog2.pinned
        assert prog2.steps <= cap

    def test_production_communicator_installs_halo_default(self, tmp_path):
        from repro.measure.production import production_communicator

        before = get_default_halo_steps()
        try:
            comm, _ = production_communicator(tmp_path, calibrate=False,
                                              halo_steps=2)
            assert get_default_halo_steps() == 2
            prog = build_halo_program((2, 2, 2), (6, 5, 4), comm)
            assert prog.steps == 2
        finally:
            set_default_halo_steps(before)


# ===========================================================================
# heterogeneous op cycles (ISSUE 5)
# ===========================================================================

#: the predictor/corrector pair with unequal per-dimension radii used
#: throughout the cycle tests
CYCLE_OPS = (StencilOp((2, 1, 1), weight=0.5), StencilOp((1, 1, 1), weight=0.25))


class TestCyclePrograms:
    def test_cycle_radii_and_sequence(self):
        assert cycle_radii(CYCLE_OPS) == (3, 2, 2)
        assert cycle_radii(STENCIL26) == (1, 1, 1)
        seq = op_sequence(CYCLE_OPS, 3)
        assert len(seq) == 6
        assert seq[0] is CYCLE_OPS[0] and seq[1] is CYCLE_OPS[1]
        assert seq[4] is CYCLE_OPS[0]
        with pytest.raises(ValueError, match="repeats"):
            op_sequence(CYCLE_OPS, 0)

    def test_stencil_cycle_matches_periodic_oracle(self):
        """Two repeats of the [predictor, corrector] cycle on one
        exchange, single periodic rank, vs the roll oracle applied
        op-by-op."""
        spec = HaloSpec(grid=(1, 1, 1), interior=(8, 7, 6),
                        radius=tuple(2 * r for r in cycle_radii(CYCLE_OPS)))
        rz, ry, rx = spec.radii
        nz, ny, nx = spec.interior
        comm = Communicator(axis_name="ranks")
        rng = np.random.default_rng(0)
        g = rng.normal(size=spec.interior).astype(np.float32)
        local = np.zeros(spec.alloc, np.float32)
        local[rz:rz + nz, ry:ry + ny, rx:rx + nx] = g

        def it(x):
            x = halo_exchange(x, spec, comm, "ranks")
            return stencil_cycle(x, spec, CYCLE_OPS, 2)

        fn = jax.jit(shard_map(it, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        out = np.asarray(fn(jnp.asarray(local)))
        want = g
        for op in op_sequence(CYCLE_OPS, 2):
            want = _stencil_np(want, op)
        np.testing.assert_allclose(
            out[rz:rz + nz, ry:ry + ny, rx:rx + nx], want,
            rtol=2e-6, atol=2e-6,
        )

    def test_cycle_exhaustion_validated(self):
        spec = HaloSpec(grid=(1, 1, 1), interior=(8, 8, 8),
                        radius=cycle_radii(CYCLE_OPS))
        x = jnp.zeros(spec.alloc, jnp.float32)
        with pytest.raises(ValueError, match="exhaust"):
            stencil_cycle(x, spec, CYCLE_OPS, 2)

    def test_cycle_program_geometry(self):
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
        prog = build_halo_program((2, 2, 2), (8, 6, 6), comm, ops=CYCLE_OPS,
                                  steps=2, schedule_policy="exact")
        assert prog.spec.radii == (6, 4, 4)
        assert prog.cycle_len == 2
        assert prog.applications == 4
        assert prog.exchanges_per_step == 0.25
        assert prog.exchanges_per_cycle == 0.5
        assert prog.plan.wire_bytes == sum(
            ct.packed_extent() for ct in prog.plan.send_cts
        )
        with pytest.raises(ValueError, match="cycle"):
            prog.op  # a 2-op program has no single 'the' op

    def test_cycle_infeasible_depth_raises(self):
        comm = Communicator(axis_name="ranks")
        with pytest.raises(ValueError, match="cannot host"):
            build_halo_program((2, 2, 2), (8, 6, 6), comm, ops=CYCLE_OPS,
                               steps=3)  # 3 * (3,2,2) exceeds (8,6,6)

    def test_cycle_fingerprint_order_sensitive_and_v1_compatible(self):
        a, b = CYCLE_OPS
        fab = program_fingerprint((2, 2, 2), (8, 6, 6), (a, b), FLOAT)
        fba = program_fingerprint((2, 2, 2), (8, 6, 6), (b, a), FLOAT)
        assert fab != fba  # the shrinking schedule is order-sensitive
        # single-op cycles keep the v1 key: decision files recorded
        # before cycles existed still pin
        f1 = program_fingerprint((2, 2, 2), (8, 6, 6), a, FLOAT)
        f1_seq = program_fingerprint((2, 2, 2), (8, 6, 6), (a,), FLOAT)
        assert f1 == f1_seq != fab

    def test_cycle_price_oracle_on_ci_params(self):
        """The auto chooser on the CI-pinned measured tables: never a
        repeat count predicted worse per application than s=1, per-op
        redundant terms split and summing to t_redundant, wire bytes
        strictly growing with depth."""
        comm = Communicator(axis_name="ranks", params=load_ci_params(),
                            policy=FixedPolicy("rows"))
        prog = build_halo_program((2, 2, 2), (9, 8, 8), comm, ops=CYCLE_OPS,
                                  steps="auto", schedule_policy="exact")
        assert prog.candidates
        by_steps = {e.steps: e for e in prog.candidates}
        assert 1 in by_steps
        assert prog.estimate.per_step <= by_steps[1].per_step
        for est in prog.candidates:
            assert est.cycle_len == 2
            assert est.applications == 2 * est.steps
            assert len(est.op_redundant) == 2
            assert est.t_redundant == pytest.approx(sum(est.op_redundant))
        wire = [by_steps[s].wire_bytes for s in sorted(by_steps)]
        assert wire == sorted(wire) and wire[0] < wire[-1]

    def test_cycle_auto_pinned_across_processes(self):
        """Pinned cycle Decision replay: the program/s=N row records the
        cycle signature, round-trips through JSON, and pins the repeat
        count in a fresh process."""
        dc = DecisionCache()
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                            decisions=dc)
        prog = build_halo_program((2, 2, 2), (8, 6, 6), comm, ops=CYCLE_OPS,
                                  steps="auto")
        assert not prog.pinned
        rows = dc.program_rows()
        assert len(rows) == 1
        assert rows[0].strategy == f"program/s={prog.steps}"
        assert rows[0].fingerprint == prog.fingerprint
        assert "cycle=[2x1x1w0.5,1x1x1w0.25]" in rows[0].signature

        dc2 = DecisionCache.from_json(dc.to_json())
        comm2 = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                             decisions=dc2)
        prog2 = build_halo_program((2, 2, 2), (8, 6, 6), comm2, ops=CYCLE_OPS,
                                   steps="auto")
        assert prog2.pinned
        assert prog2.steps == prog.steps
        assert len(dc2.program_rows()) == 1
        # a different cycle (swapped order) must NOT ride that pin
        a, b = CYCLE_OPS
        prog3 = build_halo_program((2, 2, 2), (8, 6, 6), comm2, ops=(b, a),
                                   steps="auto")
        assert not prog3.pinned

    def test_price_program_cycle_normalizes_scalar_form(self):
        """A one-op cycle prices identically through the scalar and the
        sequence signatures."""
        from repro.comm import PerfModel, plan_wire

        model = PerfModel(load_ci_params())
        plan = plan_wire((256,), (((0, 0),),), native=False)
        one = model.price_program(plan, (8, 8, 8), (1, 1, 1), 26, 2)
        seq = model.price_program(plan, (8, 8, 8), [(1, 1, 1)], [26], 2)
        assert one.total == seq.total
        assert one.per_step == seq.per_step
        assert one.applications == seq.applications == 2
        with pytest.raises(ValueError, match="match the cycle"):
            model.price_program(plan, (8, 8, 8), [(1, 1, 1)], [26, 8], 2)


CYCLE_DEEP_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import StencilOp, build_halo_program, make_program_step

# unequal per-dim radii: cycle radii (3, 2, 2); s in {1,2,3} all fit the
# (9, 6, 6) interior and divide 6 total cycle repeats
ops = [StencilOp((2, 1, 1), weight=0.5), StencilOp((1, 1, 1), weight=0.25)]
grid, interior = (2, 2, 2), (9, 6, 6)
nz, ny, nx = interior
R = 8
mesh = Mesh(np.array(jax.devices()), ("ranks",))
field = np.random.default_rng(0).normal(size=(R, nz, ny, nx)).astype(np.float32)

def run(prog, comm, state_field, iters):
    fn = make_program_step(prog, comm, mesh)
    az, ay, ax = prog.spec.alloc
    rz, ry, rx = prog.spec.radii
    state = np.zeros((R, az, ay, ax), np.float32)
    state[:, rz:rz+nz, ry:ry+ny, rx:rx+nx] = state_field
    x = jnp.asarray(state.reshape(R * az, ay, ax))
    for _ in range(iters):
        x = fn(x)
    return np.asarray(x).reshape(R, az, ay, ax)[
        :, rz:rz+nz, ry:ry+ny, rx:rx+nx]

TOTAL = 6  # cycle repeats in every variant
interiors = {}
for s in (1, 2, 3):
    comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
    prog = build_halo_program(grid, interior, comm, ops=ops, steps=s,
                              schedule_policy="exact")
    assert prog.spec.radii == (3 * s, 2 * s, 2 * s)
    fn = make_program_step(prog, comm, mesh)
    az, ay, ax = prog.spec.alloc
    counts = collective_payload_bytes(fn, jnp.zeros((R * az, ay, ax), jnp.float32))
    assert counts["ops"] == prog.plan.wire.wire_ops, (s, counts)
    assert counts["total"] == prog.plan.wire_bytes, (s, counts)
    interiors[s] = run(prog, comm, field, TOTAL // s)

np.testing.assert_array_equal(interiors[1], interiors[2])
np.testing.assert_array_equal(interiors[1], interiors[3])

# the exchange-per-application reference: one single-op program per op,
# exchanged before EVERY application
comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
ref_progs = [build_halo_program(grid, interior, comm, ops=[op], steps=1,
                                schedule_policy="exact") for op in ops]
ref = field
for _ in range(TOTAL):
    for prog in ref_progs:
        ref = run(prog, comm, ref, 1)
np.testing.assert_array_equal(interiors[1], ref)
print("CYCLE_DEEP_OK")
"""


@pytest.mark.slow
def test_cycle_bit_exact_s123_vs_per_step_reference():
    out = run_with_devices(CYCLE_DEEP_CODE, ndev=8)
    assert "CYCLE_DEEP_OK" in out


DEEP_HALO_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import StencilOp, build_halo_program, make_program_step

# per-dim stencil radii (2,1,1); depths 1..3 all divide 6 total steps
op = StencilOp((2, 1, 1))
grid, interior = (2, 2, 2), (6, 4, 4)
nz, ny, nx = interior
R = 8
mesh = Mesh(np.array(jax.devices()), ("ranks",))
field = np.random.default_rng(0).normal(size=(R, nz, ny, nx)).astype(np.float32)

TOTAL = 6
interiors = {}
for s in (1, 2, 3):
    comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
    prog = build_halo_program(grid, interior, comm, op=op, steps=s,
                              schedule_policy="exact")
    assert prog.spec.radii == (2 * s, s, s)
    fn = make_program_step(prog, comm, mesh)
    az, ay, ax = prog.spec.alloc
    rz, ry, rx = prog.spec.radii
    state = np.zeros((R, az, ay, ax), np.float32)
    state[:, rz:rz+nz, ry:ry+ny, rx:rx+nx] = field
    x = jnp.asarray(state.reshape(R * az, ay, ax))
    # one fused exchange per iteration of s stencil steps
    counts = collective_payload_bytes(fn, x)
    assert counts["ops"] == prog.plan.wire.wire_ops, (s, counts)
    assert counts["total"] == prog.plan.wire_bytes, (s, counts)
    out = x
    for _ in range(TOTAL // s):
        out = fn(out)
    interiors[s] = np.asarray(out).reshape(R, az, ay, ax)[
        :, rz:rz+nz, ry:ry+ny, rx:rx+nx]

# the naive per-step reference is s=1; every depth must be bit-exact
np.testing.assert_array_equal(interiors[1], interiors[2])
np.testing.assert_array_equal(interiors[1], interiors[3])
print("DEEP_HALO_OK")
"""


@pytest.mark.slow
def test_deep_halo_bit_exact_s123_per_dim_radii():
    out = run_with_devices(DEEP_HALO_CODE, ndev=8)
    assert "DEEP_HALO_OK" in out


# ===========================================================================
# model-priced wire-schedule choice (ROADMAP open item)
# ===========================================================================

def _two_group_case(comm):
    n = 4
    cts = [
        comm.commit(Subarray((64,), (8,), (0,), BYTE)),
        comm.commit(Subarray((64,), (8,), (16,), BYTE)),
    ]
    ring = tuple((r, (r + 1) % n) for r in range(n))
    back = tuple((r, (r - 1) % n) for r in range(n))
    return cts, (ring, back)


class TestModelPricedSchedule:
    def test_latency_heavy_params_pick_uniform(self):
        # 2 delta classes: grouped pays an extra collective launch;
        # the padding (16 extra bytes) is nearly free on the analytic
        # bandwidth — the model must buy the single padded collective
        dc = DecisionCache()
        p = SystemParams(name="lat", ici_latency=1e-3)
        comm = Communicator(axis_name="x", params=p, decisions=dc)
        cts, perms = _two_group_case(comm)
        _, plan = comm.plan_neighbor(cts, perms, schedule_policy="model")
        assert plan.schedule == "uniform"
        assert plan.wire_ops == 1
        assert plan.issued_bytes == plan.nranks * plan.seg_bytes == 32
        assert plan.padding_bytes == 16
        # the decision row records the chosen schedule AND the prices of
        # the alternatives the model rejected
        rows = [d for d in dc.log if d.strategy == "wire/uniform"]
        assert len(rows) == 1
        assert "priced[" in rows[0].signature
        assert "grouped=" in rows[0].signature
        assert rows[0].wire_bytes == 32

    def test_byte_steep_wire_table_keeps_grouped(self):
        # measured table where 32 B costs 10 ms and 16 B costs 1 ns:
        # padding is ruinous, launches are free — grouped must survive
        p = SystemParams(
            name="steep",
            wire_table=((0.0, 1e-9), (4.0, 1e-9), (5.0, 1e-2), (30.0, 1e-1)),
            wire_latency=1e-9,
        )
        comm = Communicator(axis_name="x", params=p)
        cts, perms = _two_group_case(comm)
        _, plan = comm.plan_neighbor(cts, perms, schedule_policy="model")
        assert plan.schedule == "grouped"
        assert plan.issued_bytes == plan.wire_bytes == 16

    def test_default_policy_is_model(self):
        # ROADMAP flip: plan_neighbor defaults to the model-priced
        # schedule choice — on latency-heavy analytic params the two
        # delta classes fuse into one padded uniform collective without
        # anyone passing schedule_policy
        from repro.comm import DEFAULT_SCHEDULE_POLICY

        assert DEFAULT_SCHEDULE_POLICY == "model"
        p = SystemParams(name="lat", ici_latency=1e-3)
        comm = Communicator(axis_name="x", params=p)
        cts, perms = _two_group_case(comm)
        _, plan = comm.plan_neighbor(cts, perms)
        assert plan.schedule == "uniform"
        # the padding the model may buy is bounded by the row-equalized
        # layout (the CI padded-allowance gate asserts the same bound)
        assert plan.issued_bytes <= plan.nranks * plan.seg_bytes

    def test_exact_policy_selectable(self):
        # the byte-exact ladder stays selectable per plan (the strict
        # wire-bytes CI gates request it)
        comm = Communicator(axis_name="x")
        cts, perms = _two_group_case(comm)
        _, plan = comm.plan_neighbor(cts, perms, schedule_policy="exact")
        assert plan.schedule == "grouped"
        assert plan.issued_bytes == plan.wire_bytes
        with pytest.raises(ValueError, match="schedule_policy"):
            comm.plan_neighbor(cts, perms, schedule_policy="nope")

    def test_large_grid_threshold_survives_model_pricing(self):
        # past rank_factor * ngroups the fused layouts are mostly dead
        # rows/metadata — a cost t_link cannot see — so the model
        # chooser must not offer them even when ragged/uniform look
        # cheap on paper
        from repro.comm import plan_wire

        n = 32
        ring = tuple((r, (r + 1) % n) for r in range(n))
        plan = plan_wire((64,), (ring,), native=False)
        assert plan.schedule == "grouped"
        model = PerfModel(SystemParams(name="lat", ici_latency=1e-3))
        new_plan, costs = model.choose_wire_schedule(plan, native=True)
        assert set(costs) == {"grouped"}
        assert new_plan.schedule == "grouped"

    def test_reschedule_validation_and_fingerprint(self):
        from repro.comm import plan_wire

        plan = plan_wire((8, 4), (((0, 0),), ((0, 0),)), native=False)
        same = reschedule(plan, plan.schedule)
        assert same is plan
        with pytest.raises(ValueError, match="unknown wire schedule"):
            reschedule(plan, "carrier-pigeon")
        # a rescheduled plan keeps the layout but re-fingerprints
        grouped = reschedule(plan, "grouped")
        assert grouped.segments == plan.segments
        assert grouped.fingerprint != plan.fingerprint

    def test_model_scheduled_uniform_executes_correctly(self):
        # the rescheduled plan must still move the right bytes end-to-end
        p = SystemParams(name="lat", ici_latency=1e-3)
        comm = Communicator(axis_name="x", params=p)
        send_cts = [
            comm.commit(Subarray((64,), (8,), (0,), BYTE)),
            comm.commit(Subarray((64,), (4,), (16,), BYTE)),
        ]
        recv_cts = [
            comm.commit(Subarray((64,), (8,), (32,), BYTE)),
            comm.commit(Subarray((64,), (4,), (48,), BYTE)),
        ]
        perms = [[(0, 0)], [(0, 0)]]
        strats, plan = comm.plan_neighbor(send_cts, perms,
                                          schedule_policy="model")

        def body(b):
            return comm.neighbor_alltoallv(
                b, send_cts, recv_cts, perms, plan=plan, strategies=strats
            )

        fn = jax.jit(shard_map(body, mesh=_mesh1("x"), in_specs=P(),
                               out_specs=P(), check_vma=False))
        out = np.asarray(fn(jnp.arange(64, dtype=jnp.uint8)))
        want = np.arange(64, dtype=np.uint8)
        want[32:40] = want[0:8]
        want[48:52] = want[16:20]
        np.testing.assert_array_equal(out, want)
        counts = collective_payload_bytes(fn, jnp.arange(64, dtype=jnp.uint8))
        assert counts["ops"] == plan.wire_ops
        assert counts["total"] == plan.issued_bytes


# ===========================================================================
# Int8Wire per-block scales
# ===========================================================================

class TestInt8PerBlock:
    def _big_ct(self, comm):
        # 20 rows x 20 floats = 400 member floats -> 2 blocks of <=256
        # (Subarray dims innermost-first: rows 4..23, cols 0..19)
        return comm.commit(Subarray((32, 32), (20, 20), (0, 4), FLOAT))

    def test_wire_bytes_grow_per_block(self):
        comm = Communicator(axis_name="x")
        ct = self._big_ct(comm)
        nfloats = ct.size // 4
        assert nfloats == 400
        assert INT8_WIRE.wire_bytes(ct) == 2 * 4 + nfloats
        legacy = Int8Wire(block_elems=None)
        assert legacy.wire_bytes(ct) == 4 + nfloats
        # small payloads: identical format (one block == one payload)
        small = comm.commit(Subarray((16, 16), (4, 8), (2, 0), FLOAT))
        assert INT8_WIRE.wire_bytes(small) == legacy.wire_bytes(small)

    def test_per_block_scale_widens_usable_range(self):
        """A payload mixing tiny and huge magnitudes: one payload-wide
        scale crushes the tiny block to zero; per-block scales keep it."""
        comm = Communicator(axis_name="x",
                            policy=FixedPolicy(INT8_WIRE.name))
        ct = self._big_ct(comm)
        src = np.zeros((32, 32), np.float32)
        rng = np.random.default_rng(0)
        # region rows 4..23, cols 0..19, packed row-major: block 0 is
        # floats 0..255 (rows 4..15 + most of straddling row 16), block 1
        # is the rest.  Tiny magnitudes through row 16, huge after.
        src[4:17, 0:20] = rng.uniform(1e-3, 2e-3, size=(13, 20))
        src[17:24, 0:20] = rng.uniform(500.0, 1000.0, size=(7, 20))

        def body(b):
            return comm.sendrecv(b, jnp.zeros_like(b), ct, [(0, 0)])

        fn = jax.jit(shard_map(body, mesh=_mesh1("x"), in_specs=P(),
                               out_specs=P(), check_vma=False))
        out = np.asarray(fn(jnp.asarray(src)))
        # assert only the rows fully inside each block (row 16 straddles:
        # its tail rides block 1's huge scale and rounds to ~0)
        small = np.s_[4:16, 0:20]
        big = np.s_[17:24, 0:20]
        # per-block: the tiny block quantizes against its own max
        small_scale = np.abs(src[small]).max() / 127.0
        np.testing.assert_allclose(out[small], src[small],
                                   atol=small_scale / 2 + 1e-7)
        big_scale = np.abs(src[big]).max() / 127.0
        np.testing.assert_allclose(out[big], src[big],
                                   atol=big_scale / 2 + 1e-4)
        # a payload-wide scale could not represent the tiny block at all
        payload_scale = np.abs(src[4:24, 0:20]).max() / 127.0
        assert small_scale < payload_scale / 1000
        assert np.abs(out[small] - src[small]).max() < payload_scale / 100

    def test_legacy_per_payload_format_still_readable(self):
        comm = Communicator(axis_name="x")
        ct = self._big_ct(comm)
        rng = np.random.default_rng(1)
        src = np.zeros((32, 32), np.float32)
        src[4:24, 0:20] = rng.normal(size=(20, 20)).astype(np.float32)
        legacy = Int8Wire(block_elems=None)
        wire = legacy.pack(jnp.asarray(src), ct)
        assert wire.shape[0] == legacy.wire_bytes(ct)
        # the default (per-block) instance decodes the one-scale payload
        out = np.asarray(
            INT8_WIRE.unpack_wire(comm, jnp.zeros((32, 32), jnp.float32),
                                  wire, ct)
        )
        scale = np.abs(src[4:24, 0:20]).max() / 127.0
        np.testing.assert_allclose(out[4:24, 0:20], src[4:24, 0:20],
                                   atol=scale / 2 + 1e-7)

    def test_truncated_wire_refused(self):
        comm = Communicator(axis_name="x")
        ct = self._big_ct(comm)
        bad = jnp.zeros((4 * 3 + 400,), jnp.uint8)  # 3 scales for 2 blocks
        with pytest.raises(ValueError, match="scales"):
            INT8_WIRE.unpack_wire(comm, jnp.zeros((32, 32), jnp.float32),
                                  bad, ct)


# ===========================================================================
# RleWire: lossless zero-run wire compression
# ===========================================================================

class TestRleWire:
    def _ct(self, comm):
        return comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))

    def test_wire_bytes_and_plan_accounting(self):
        from repro.comm import RLE_WIRE, RleWire

        comm = Communicator(axis_name="x",
                            policy=FixedPolicy(RleWire.name))
        ct = self._ct(comm)
        assert RLE_WIRE.wire_bytes(ct) == ct.size + 8
        assert RLE_WIRE.wire_segment(ct).nbytes == ct.size + 8
        # the WirePlan carries the capacity bytes (header included), and
        # the traced collective moves exactly that
        strats, plan = comm.plan_neighbor([ct], [[(0, 0)]],
                                          schedule_policy="exact")
        assert strats[0].name == RleWire.name
        assert plan.wire_bytes == ct.size + 8

        recv = comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))

        def body(b):
            return comm.neighbor_alltoallv(
                b, [ct], [recv], [[(0, 0)]], plan=plan, strategies=strats
            )

        fn = jax.jit(shard_map(body, mesh=_mesh1("x"), in_specs=P(),
                               out_specs=P(), check_vma=False))
        counts = collective_payload_bytes(fn, jnp.zeros((32, 32), jnp.float32))
        assert counts["total"] == plan.issued_bytes == ct.size + 8

    def test_zero_run_payload_rides_rle_mode_exactly(self):
        from repro.comm import RLE_WIRE

        comm = Communicator(axis_name="x")
        ct = self._ct(comm)
        src = np.zeros((32, 32), np.float32)
        src[10:12, 4:20] = 3.25  # a few runs in a sea of zeros
        wire = RLE_WIRE.pack(jnp.asarray(src), ct)
        assert wire.shape[0] == RLE_WIRE.wire_bytes(ct)
        mode, nruns = np.asarray(wire[:8]).view(np.uint32)
        assert mode == 1  # fits the run capacity -> rle mode
        assert nruns <= ct.size // 5
        out = np.asarray(RLE_WIRE.unpack_wire(
            comm, jnp.zeros((32, 32), jnp.float32), wire, ct))
        # LOSSLESS: bit-exact, not allclose
        np.testing.assert_array_equal(out[4:20, 4:20], src[4:20, 4:20])

    def test_incompressible_payload_stored_exactly(self):
        from repro.comm import RLE_WIRE

        comm = Communicator(axis_name="x")
        ct = self._ct(comm)
        rng = np.random.default_rng(0)
        src = rng.normal(size=(32, 32)).astype(np.float32)
        wire = RLE_WIRE.pack(jnp.asarray(src), ct)
        mode, _ = np.asarray(wire[:8]).view(np.uint32)
        assert mode == 0  # too many runs -> stored-block fallback
        out = np.asarray(RLE_WIRE.unpack_wire(
            comm, jnp.zeros((32, 32), jnp.float32), wire, ct))
        np.testing.assert_array_equal(out[4:20, 4:20], src[4:20, 4:20])

    def test_end_to_end_sendrecv_both_modes(self):
        from repro.comm import RleWire

        comm = Communicator(axis_name="x", policy=FixedPolicy(RleWire.name))
        ct = self._ct(comm)

        def body(b):
            return comm.sendrecv(b, jnp.zeros_like(b), ct, [(0, 0)])

        fn = jax.jit(shard_map(body, mesh=_mesh1("x"), in_specs=P(),
                               out_specs=P(), check_vma=False))
        sparse = np.zeros((32, 32), np.float32)
        sparse[5, 5] = 1.0
        dense = np.random.default_rng(1).normal(size=(32, 32)).astype(np.float32)
        for src in (sparse, dense):
            out = np.asarray(fn(jnp.asarray(src)))
            np.testing.assert_array_equal(out[4:20, 4:20], src[4:20, 4:20])

    def test_selectable_only_with_probe_and_wire_only(self):
        from repro.comm import RLE_WIRE, default_registry

        assert RLE_WIRE.name in default_registry()
        # byte-exact in both modes, so the strategy is selectable — but
        # priced at CAPACITY (member + 8 B, strictly worse than rows)
        # unless the selection carries a payload probe, so the model
        # must still never auto-pick it without one
        assert RLE_WIRE.selectable
        assert RLE_WIRE.supports_varlen
        assert RLE_WIRE.wire_only
        comm = Communicator(axis_name="x")
        ct = self._ct(comm)
        assert comm.select(ct, wire=True).name != RLE_WIRE.name
        with pytest.raises(TypeError, match="wire-only"):
            RLE_WIRE.unpack(jnp.zeros(4), jnp.zeros(4, jnp.uint8), ct)

    def test_wrong_length_refused(self):
        from repro.comm import RLE_WIRE

        comm = Communicator(axis_name="x")
        ct = self._ct(comm)
        with pytest.raises(ValueError, match="rle wire"):
            RLE_WIRE.unpack_wire(comm, jnp.zeros((32, 32), jnp.float32),
                                 jnp.zeros((ct.size,), jnp.uint8), ct)


# ===========================================================================
# native ragged collective (gated integration test)
# ===========================================================================

RAGGED_NATIVE_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import HaloSpec, make_halo_plan, make_halo_step

spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=2)
r = spec.radius
nz, ny, nx = spec.interior
az, ay, ax = spec.alloc
R = spec.nranks
comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
plan = make_halo_plan(spec, comm)
# with the native collective available the 2x2x2 ladder must pick it
assert plan.wire.schedule == "ragged", plan.wire.schedule
assert plan.wire.wire_ops == 1

mesh = Mesh(np.array(jax.devices()), ("ranks",))
step = make_halo_step(spec, comm, mesh)

gz, gy, gx = 2 * nz, 2 * ny, 2 * nx
gvals = np.arange(gz * gy * gx, dtype=np.float32).reshape(gz, gy, gx)
locals_np = np.full((R, az, ay, ax), -1.0, np.float32)
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    locals_np[rank, r:r+nz, r:r+ny, r:r+nx] = gvals[
        cz*nz:(cz+1)*nz, cy*ny:(cy+1)*ny, cx*nx:(cx+1)*nx]
x0 = jnp.asarray(locals_np.reshape(R * az, ay, ax))

# byte accounting: ONE ragged collective moving exactly the plan bytes
counts = collective_payload_bytes(step, x0)
assert counts["ops"] == 1, counts
assert counts.get("ragged_all_to_all", 0) == plan.wire_bytes, counts
assert counts["total"] == plan.wire_bytes == sum(
    ct.packed_extent() for ct in plan.send_cts)

# bit-exactness: every halo cell equals the periodic global value
out = np.asarray(step(x0)).reshape(R, az, ay, ax)
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    zz = (np.arange(az) - r + cz * nz) % gz
    yy = (np.arange(ay) - r + cy * ny) % gy
    xx = (np.arange(ax) - r + cx * nx) % gx
    np.testing.assert_array_equal(out[rank], gvals[np.ix_(zz, yy, xx)],
                                  err_msg=f"rank {rank}")
print("RAGGED_NATIVE_OK")

# with the native collective available, the varlen (length-aware
# compressed) transport must prefer it too: a zero-heavy probed payload
# plans schedule=varlen on a fused layout and the traced exchange is
# ONE ragged_all_to_all moving exactly the stream bytes
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import Subarray, FLOAT

vcomm = Communicator(axis_name="ranks")
vct = vcomm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
vsrc = np.zeros((32, 32), np.float32)
vsrc[10, 6] = 3.0
vstrats, vplan = vcomm.plan_neighbor(
    [vct], [[(0, 0)]], probe=jnp.asarray(vsrc))
assert vplan.schedule == "varlen", vplan.schedule
assert vplan.fused, "varlen layout must stay native-ragged eligible"

def vbody(b):
    return vcomm.neighbor_alltoallv(
        b, [vct], [vct], [[(0, 0)]], plan=vplan, strategies=vstrats)

vfn = jax.jit(shard_map(
    vbody, mesh=Mesh(np.array(jax.devices()[:1]), ("ranks",)),
    in_specs=P(), out_specs=P(), check_vma=False))
vcounts = collective_payload_bytes(vfn, jnp.asarray(vsrc))
assert vcounts.get("ragged_all_to_all", 0) == vplan.effective_wire_bytes, vcounts
assert vcounts["total"] == vplan.issued_bytes < vplan.wire_bytes, vcounts
vout = np.asarray(vfn(jnp.asarray(vsrc)))
np.testing.assert_array_equal(vout[4:20, 4:20], vsrc[4:20, 4:20])
print("VARLEN_NATIVE_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not has_ragged_all_to_all(),
    reason="needs lax.ragged_all_to_all (JAX >= 0.5; the pinned 0.4.37 "
           "lowers the ragged schedule to grouped ppermutes instead)",
)
def test_native_ragged_schedule_end_to_end():
    out = run_with_devices(RAGGED_NATIVE_CODE, ndev=8)
    assert "RAGGED_NATIVE_OK" in out
    assert "VARLEN_NATIVE_OK" in out
