"""flash_attention fwd/bwd vs a dense reference (values AND grads)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.layers import flash_attention


def dense_reference(q, k, v, causal=True, window=None, q_offset=0):
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kf) / np.sqrt(D)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        ok &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(ok[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


RNG = np.random.default_rng(0)


def _mk(B, Sq, Sk, H, KVH, D, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dtype) * 0.5
    k = jnp.asarray(RNG.normal(size=(B, Sk, KVH, D)), dtype) * 0.5
    v = jnp.asarray(RNG.normal(size=(B, Sk, KVH, D)), dtype) * 0.5
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16), (False, None)])
@pytest.mark.parametrize("chunk", [8, 32, 64])
def test_forward_matches_dense(causal, window, chunk):
    q, k, v = _mk(2, 64, 64, 4, 2, 16)
    got = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    want = dense_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16), (False, None)])
def test_grads_match_dense(causal, window):
    q, k, v = _mk(1, 32, 32, 4, 2, 16)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window, chunk=8)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_dense(q, k, v):
        o = dense_reference(q, k, v, causal=causal, window=window)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gd, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4,
            err_msg=f"d{name}",
        )


def test_gqa_grouping_and_offset():
    q, k, v = _mk(2, 4, 20, 8, 2, 16)
    got = flash_attention(q, k, v, causal=True, q_offset=16, chunk=5)
    want = dense_reference(q, k, v, causal=True, q_offset=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("merged", [True, False])
def test_both_head_layouts_match_dense(merged):
    """The merged-H and split-(KVH,G) internal layouts are numerically
    identical (layout choice is a pure sharding decision)."""
    from repro.models.layers import _flash_vjp

    q, k, v = _mk(1, 32, 32, 4, 2, 16)
    fa = _flash_vjp(True, None, 0, 8, merged)
    got = fa(q, k, v)
    want = dense_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    gf = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(fa(q, k, v))),
                  argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(
        lambda q, k, v: jnp.sum(jnp.sin(dense_reference(q, k, v))),
        argnums=(0, 1, 2),
    )(q, k, v)
    for a, b in zip(gf, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
