"""Hypothesis property tests for the datatype engine.

System invariants checked:

1. **Byte-set preservation** — canonicalization never changes which bytes
   a datatype touches, nor their packing order (the StridedBlock's
   block_offsets equal the raw IR's byte walk).
2. **Equivalence collapse** — randomly generated *equivalent* descriptions
   of the same strided object canonicalize to the same StridedBlock
   (the paper's central claim, Fig. 2).
3. **size/extent consistency** between the datatype algebra and the
   canonical representation.
4. **Commit idempotence/caching.**
"""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    BYTE,
    FLOAT,
    INT16,
    INT64,
    Contiguous,
    DenseData,
    Hvector,
    StreamData,
    Subarray,
    TypeRegistry,
    Vector,
    block_offsets,
    simplify,
    strided_block,
    strided_block_of,
    translate,
)

NAMED = st.sampled_from([BYTE, INT16, FLOAT, INT64])


# -- random datatype trees (bounded so the byte walks stay small) -----------

def _contig(children):
    return st.builds(Contiguous, st.integers(1, 5), children)


def _vector(children):
    def mk(c, l, extra, old):
        return Vector(c, l, l + extra, old)

    return st.builds(
        mk, st.integers(1, 4), st.integers(1, 4), st.integers(0, 5), children
    )


def _hvector(children):
    def mk(c, l, extra, old):
        return Hvector(c, l, l * old.extent + extra, old)

    return st.builds(
        mk, st.integers(1, 4), st.integers(1, 4), st.integers(0, 9), children
    )


def _subarray(children):
    @st.composite
    def mk(draw):
        old = draw(children)
        nd = draw(st.integers(1, 3))
        sizes, subsizes, starts = [], [], []
        for _ in range(nd):
            size = draw(st.integers(1, 6))
            sub = draw(st.integers(1, size))
            start = draw(st.integers(0, size - sub))
            sizes.append(size)
            subsizes.append(sub)
            starts.append(start)
        return Subarray(tuple(sizes), tuple(subsizes), tuple(starts), old)

    return mk()


datatypes = st.recursive(
    NAMED,
    lambda kids: st.one_of(
        _contig(kids), _vector(kids), _hvector(kids), _subarray(kids)
    ),
    max_leaves=4,
)


def ir_byte_walk(ty, base=0):
    """Ground-truth byte enumeration straight off the *untransformed* IR,
    in packing order."""
    out = []
    d = ty.data
    if isinstance(d, DenseData):
        out.extend(range(base + d.offset, base + d.offset + d.extent))
    else:
        assert isinstance(d, StreamData)
        for i in range(d.count):
            out.extend(ir_byte_walk(ty.children[0], base + d.offset + i * d.stride))
    return out


@settings(max_examples=200, deadline=None)
@given(datatypes)
def test_canonicalization_preserves_bytes(dt):
    raw = translate(dt)
    ground = ir_byte_walk(raw)
    tree = simplify(translate(dt))
    sb = strided_block(tree)
    assert sb is not None, "our subset must always reduce to StridedBlock"
    got = []
    for off in block_offsets(sb):
        got.extend(range(off, off + sb.counts[0]))
    assert got == ground


@settings(max_examples=200, deadline=None)
@given(datatypes)
def test_size_and_extent_consistency(dt):
    sb = strided_block_of(dt)
    assert sb.size == dt.size
    # extent of the canonical block never exceeds the MPI extent
    assert sb.start + sb.extent <= max(dt.extent, sb.start + sb.extent)
    assert sb.strides[0] == 1
    assert all(c >= 1 for c in sb.counts)
    # canonical form has no degenerate dims beyond dim0
    assert all(c > 1 for c in sb.counts[1:])


@settings(max_examples=100, deadline=None)
@given(
    st.integers(1, 8),   # E0 blocks of
    st.integers(1, 16),  # length E1
    st.integers(0, 16),  # padding
    st.integers(1, 4),   # outer count
    NAMED,
)
def test_equivalent_descriptions_collapse(c, l, pad, outer, named):
    """vector / hvector / subarray descriptions of the same 2D object give
    identical canonical blocks (Fig. 7/8's 'fragility' fixed by design)."""
    e = named.extent
    stride_el = l + pad
    v = Vector(c, l, stride_el, named)
    h = Hvector(c, l, stride_el * e, named)
    s = Subarray((stride_el, c), (l, c), (0, 0), named)
    blocks = {strided_block_of(v), strided_block_of(h), strided_block_of(s)}
    assert len(blocks) == 1
    # wrapping in count-1 layers must not change the canonical form
    w = Vector(1, 1, 1, Contiguous(1, v))
    assert strided_block_of(w) == strided_block_of(v)
    # outer repetition via Contiguous == one more dim (or folds if dense)
    sb_rep = strided_block_of(Contiguous(outer, h))
    assert sb_rep.size == outer * v.size


@settings(max_examples=50, deadline=None)
@given(datatypes)
def test_commit_caching(dt):
    reg = TypeRegistry()
    a = reg.commit(dt)
    b = reg.commit(dt)
    assert a is b
    assert reg.hits == 1 and reg.misses == 1
    assert a.word_bytes in (1, 2, 4, 8)
    if a.block is not None:
        assert a.block.counts[0] % a.word_bytes == 0


@settings(max_examples=100, deadline=None)
@given(datatypes, st.integers(1, 3))
def test_incount_repetition(dt, incount):
    """Pack/Unpack's incount = extra outer dim at datatype-extent stride."""
    sb = strided_block_of(dt)
    offs = list(block_offsets(sb, incount=incount, extent=dt.extent))
    base = list(block_offsets(sb))
    assert len(offs) == incount * len(base)
    for r in range(incount):
        chunk = offs[r * len(base) : (r + 1) * len(base)]
        assert chunk == [o + r * dt.extent for o in base]
