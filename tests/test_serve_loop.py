"""Serving-loop behaviour: continuous batching, slot reuse, completion."""

import numpy as np

from repro.configs.registry import smoke_config
from repro.launch.serve import Request, ServeLoop


def test_serve_completes_all_requests():
    cfg = smoke_config("qwen2-0.5b")
    loop = ServeLoop(cfg, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size, 5)),
                max_new=4)
        for i in range(5)  # more requests than slots -> queueing
    ]
    done = loop.run(reqs)
    assert len(done) == 5
    assert all(len(v) == 4 for v in done.values())


def test_serve_deterministic_per_prompt():
    cfg = smoke_config("qwen2-0.5b")
    prompt = [3, 1, 4, 1, 5]
    outs = []
    for _ in range(2):
        loop = ServeLoop(cfg, batch_size=1, max_len=32)
        done = loop.run([Request(rid=0, prompt=list(prompt), max_new=6)])
        outs.append(done[0])
    assert outs[0] == outs[1]


def test_slot_reuse():
    cfg = smoke_config("qwen2-0.5b")
    loop = ServeLoop(cfg, batch_size=1, max_len=64)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new=2) for i in range(3)]
    done = loop.run(reqs)
    assert len(done) == 3  # one slot served three requests sequentially
